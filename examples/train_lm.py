"""End-to-end training driver: a ~100M-param LM under the HFP8 recipe with
checkpointing, loss-scale tracking, straggler watch and resume.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 30
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is the deliverable configuration (~100M params, a few
hundred steps); tiny is a CPU-minute smoke of the same path. Both resume
from ckpt_dir automatically (kill it mid-run and rerun to see).
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.policy import POLICIES
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.hlo_analysis import format_packed_footprint
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import make_train_state, make_train_step
from repro.train.trainer import Trainer

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                 d_ff=512, vocab_size=2048, seq=64, batch=8),
    "30m": dict(n_layers=6, d_model=384, n_heads=6, n_kv_heads=6,
                d_ff=1536, vocab_size=32768, seq=256, batch=8),
    "100m": dict(n_layers=12, d_model=640, n_heads=10, n_kv_heads=10,
                 d_ff=2560, vocab_size=50304, seq=512, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--policy", default="hfp8", choices=sorted(POLICIES))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"lm-{args.preset}", family="dense",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], head_dim=p["d_model"] // p["n_heads"],
        policy_name=args.policy, attn_q_chunk=p["seq"])
    model = build_model(cfg)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(
        jax.eval_shape(model.init, jax.random.key(0))))
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"policy={args.policy}")
    # what the packed payload pipeline (DESIGN.md §10) buys per operand
    print(format_packed_footprint(args.policy))

    opt = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=max(args.steps, 100))
    state = make_train_state(model, jax.random.key(0), opt)
    step = make_train_step(model, opt, microbatches=args.microbatches,
                           impl="xla")
    data = SyntheticTokens(DataConfig(cfg.vocab_size, p["seq"], p["batch"]))
    trainer = Trainer(model, step, state, data, ckpt_dir=args.ckpt_dir,
                      save_every=args.save_every)
    if trainer.start_step:
        print(f"[train_lm] resumed from step {trainer.start_step}")
    log = trainer.run(args.steps)
    for m in log[:: max(len(log) // 10, 1)]:
        print(f"  step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.2f}  {m['step_time_s']*1e3:.0f} ms")
    print(f"[train_lm] done. stragglers observed: {trainer.straggler_count}")


if __name__ == "__main__":
    main()
