"""Quickstart: the paper's primitive, end to end, in two minutes on CPU.

1. ExSdotp semantics: fused vs cascaded accumulation accuracy (Table IV in
   miniature);
2. the expanding-GEMM Pallas kernel (interpret mode) vs its oracle;
3. a tiny quantized-trained transformer (default HFP8: forward fp8-E4M3,
   backward fp8-E5M2, fp32 accumulation everywhere; ``--policy mxfp6``
   or ``mxfp4`` runs the packed sub-byte MX pipeline instead) — loss
   goes down;
4. greedy decoding from the trained model.

Run:  PYTHONPATH=src python examples/quickstart.py [--policy mxfp4]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import exsdotp as X
from repro.core import formats as F
from repro.core.policy import POLICIES
from repro.kernels import ops, ref
from repro.launch.hlo_analysis import format_packed_footprint
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.serve.decode import generate
from repro.train.train_step import make_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--policy", default="hfp8", choices=sorted(POLICIES))
ARGS = ap.parse_args()

print("=" * 64)
print("1) ExSdotp: fused 3-term add beats the ExFMA cascade")
rng = np.random.default_rng(0)
a = F.quantize_np(rng.normal(0, 1, 256), "fp8")
b = F.quantize_np(rng.normal(0, 1, 256), "fp8")
exact = float(a @ b)
fused = X.exsdotp_chain_np(a, b, "fp8")
casc = X.exfma_chain_np(a, b, "fp8")
print(f"   exact={exact:+.6f} fused={fused:+.6f} (err {abs(fused-exact):.2e})"
      f" cascade={casc:+.6f} (err {abs(casc-exact):.2e})")

print("=" * 64)
print("2) Pallas expanding GEMM (interpret mode) == oracle")
A = jnp.asarray(rng.normal(0, 1, (64, 128)), jnp.float8_e4m3)
B = jnp.asarray(rng.normal(0, 1, (128, 32)), jnp.float8_e5m2)
out = ops.exsdotp_gemm(A, B, 1.0, impl="pallas_interpret", blocks=(32, 32, 64))
want = ref.exsdotp_gemm_ref(A, B, 1.0)
print(f"   max|kernel - oracle| = {float(jnp.max(jnp.abs(out - want))):.2e}")

print("=" * 64)
print(f"3) {ARGS.policy} training (quantized fwd/bwd, fp32 accum)")
# the packed-payload footprint this policy's GEMM operands occupy
# (DESIGN.md §10): sub-byte MX policies really store 0.75 / 0.5 B/elem
print(format_packed_footprint(ARGS.policy))
cfg = dataclasses.replace(ARCHS["qwen2.5-3b"].reduced(), vocab_size=64,
                          policy_name=ARGS.policy)
model = build_model(cfg)
opt = AdamWConfig(lr=3e-3, warmup_steps=5, schedule="constant")
state = make_train_state(model, jax.random.key(0), opt)
step = jax.jit(make_train_step(model, opt, impl="xla"))
# learnable synthetic task: tokens follow t+1 = (t*5+1) mod V
toks = np.zeros((8, 33), np.int32)
toks[:, 0] = rng.integers(0, 64, 8)
for i in range(32):
    toks[:, i + 1] = (toks[:, i] * 5 + 1) % 64
toks = jnp.asarray(toks)
losses = []
for i in range(30):
    state, m = step(state, toks)
    losses.append(float(m["loss"]))
print(f"   loss: step0={losses[0]:.3f} -> step29={losses[-1]:.3f} "
      f"(scale={float(m.get('loss_scale', 1.0)):.0f})")
assert losses[-1] < losses[0], "HFP8 training failed to learn"

print("=" * 64)
print("4) greedy decode with KV cache")
out = generate(model, state["params"], toks[:2, :4], max_new_tokens=6,
               max_len=64)
print(f"   prompt {np.asarray(toks[0,:4])} -> generated {np.asarray(out[0])}")
print("   expected continuation:",
      [(int(toks[0, 3]) * pow(5, k+1, 64) + sum(pow(5, j, 64) for j in range(k+1))) % 64
       for k in range(6)])
print("done.")
