"""Serving example: batched prefill + KV-cache decode with request batching.

Simulates a decode server: a queue of variable-length prompts is batched,
prefilled via per-token cache fill, then decoded in lockstep with greedy
sampling; reports per-token latency and throughput.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --new-tokens 16
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import build_model
from repro.serve.decode import make_serve_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()   # CPU-sized variant of the real arch
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    _, serve_step = make_serve_fns(model)
    step = jax.jit(serve_step)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len))
    cache = model.init_cache(args.batch, args.max_len)

    # prefill by cache fill (per position; production would use a fused
    # prefill kernel — same cache layout either way)
    t0 = time.perf_counter()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = step(params, jnp.asarray(prompts[:, i]), cache)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    toks = []
    t0 = time.perf_counter()
    for i in range(args.new_tokens):
        tok = jnp.argmax(logits, axis=-1)
        toks.append(np.asarray(tok))
        logits, cache = step(params, tok, cache)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    out = np.stack(toks, 1)
    print(f"[serve_lm] arch={cfg.name} batch={args.batch}")
    print(f"  prefill: {args.prompt_len} tok in {t_prefill*1e3:.0f} ms")
    print(f"  decode : {args.new_tokens} tok in {t_decode*1e3:.0f} ms "
          f"({args.batch*args.new_tokens/t_decode:.1f} tok/s incl. compile)")
    print(f"  sample continuation[0]: {out[0][:10]}")


if __name__ == "__main__":
    main()
