"""Serving example: continuous batching over the packed paged KV cache.

Simulates a decode server: a queue of variable-length prompts flows
through ``serve.scheduler.ContinuousBatcher`` — block prefill into
freshly allocated pages, lockstep decode, mid-flight admission into
slots freed by finished sequences.  Under an MX ``--policy`` the cache
pages hold packed codec payloads (DESIGN.md §12); the footprint line
shows the HBM bytes each sequence pins vs bf16 pages.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --new-tokens 16 \
        --policy mxfp8
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.configs.base import ModelConfig
from repro.core.policy import POLICIES
from repro.launch.hlo_analysis import format_serve_cache_footprint
from repro.models import build_model
from repro.serve.scheduler import ContinuousBatcher, ServeRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--policy", default="mxfp8", choices=sorted(POLICIES))
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (requests = 2x batch, so admission "
                         "into freed slots is exercised)")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    # CPU-sized variant of the real arch; head_dim widened to a whole
    # scale group so the MX policies serve *packed* pages (reduced()
    # keeps hd=16, which would fall back to carrier pages)
    cfg = dataclasses.replace(ARCHS[args.arch].reduced(),
                              head_dim=32, policy_name=args.policy)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"[serve_lm] arch={cfg.name} policy={args.policy} "
          f"slots={args.batch}")
    print(format_serve_cache_footprint(cfg, args.policy, args.max_len,
                                       page_size=args.page_size))

    rng = np.random.default_rng(0)
    reqs = [ServeRequest(i, rng.integers(0, cfg.vocab_size,
                                         rng.integers(4, args.prompt_len + 1)),
                         args.new_tokens)
            for i in range(2 * args.batch)]
    cb = ContinuousBatcher(model, params, max_batch=args.batch,
                           max_len=args.max_len, page_size=args.page_size)
    t0 = time.perf_counter()
    out = cb.run(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in out.values())
    print(f"  {len(reqs)} requests, {n_tok} tokens in {dt*1e3:.0f} ms "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    print(f"  sample continuation[0]: {out[0][:10]}")


if __name__ == "__main__":
    main()
