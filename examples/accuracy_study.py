"""Accuracy study: (a) Table IV reproduction; (b) HFP8 vs BF16 vs FP32
end-to-end training-loss curves on the same tiny LM — the paper's premise
("low-precision training works when you accumulate wide") verified through
the whole framework stack.

    PYTHONPATH=src python examples/accuracy_study.py [--steps 40]
"""
import argparse
import dataclasses

import jax
import numpy as np

from benchmarks import table4_accuracy
from repro.configs import ARCHS
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import make_train_state, make_train_step


def train_curve(policy: str, steps: int):
    cfg = dataclasses.replace(ARCHS["stablelm-1.6b"].reduced(),
                              vocab_size=128, policy_name=policy)
    model = build_model(cfg)
    opt = AdamWConfig(lr=2e-3, warmup_steps=5, schedule="constant")
    state = make_train_state(model, jax.random.key(0), opt)
    step = jax.jit(make_train_step(model, opt, impl="xla"))
    rng = np.random.default_rng(0)
    toks = np.zeros((8, 33), np.int32)
    toks[:, 0] = rng.integers(0, 128, 8)
    for i in range(32):
        toks[:, i + 1] = (toks[:, i] * 3 + 7) % 128
    losses = []
    for _ in range(steps):
        state, m = step(state, toks)
        losses.append(float(m["loss"]))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    print("== Table IV reproduction (relative error vs FP64 golden) ==")
    table4_accuracy.main(trials=15)

    print("\n== end-to-end: same model under different policies ==")
    print("policy,loss_step0,loss_final")
    finals = {}
    for pol in ("fp32", "bf16", "hfp8"):
        ls = train_curve(pol, args.steps)
        finals[pol] = ls[-1]
        print(f"{pol},{ls[0]:.4f},{ls[-1]:.4f}")
    gap = finals["hfp8"] - finals["fp32"]
    print(f"hfp8-vs-fp32 final-loss gap: {gap:+.4f} "
          f"({'OK: low-precision training tracks fp32' if gap < 0.5 else 'DEGRADED'})")


if __name__ == "__main__":
    main()
