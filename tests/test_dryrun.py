"""Dry-run infrastructure tests.

The production meshes need 512 fake devices, which must be configured
before jax initializes — so mesh-dependent checks run in a subprocess.
The HLO analyzer is validated in-process on small 1-device modules.
"""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze
from repro.configs import ARCHS
from repro.configs.base import SHAPES
from repro.launch.specs import cell_is_applicable, input_specs


def test_analyzer_matches_xla_on_scanfree_module():
    def g(w1, w2, x):
        return jnp.tanh(jnp.tanh(x @ w1) @ w2).sum()

    sh = lambda *s: jax.ShapeDtypeStruct(s, jnp.bfloat16)
    co = jax.jit(g).lower(sh(256, 256), sh(256, 256), sh(128, 256)).compile()
    ours = analyze(co.as_text())
    xla = co.cost_analysis()
    if isinstance(xla, list):  # older jax wraps the dict in a list
        xla = xla[0]
    assert abs(ours["flops"] / xla["flops"] - 1) < 0.1
    assert abs(ours["bytes"] / xla["bytes accessed"] - 1) < 0.25


def test_analyzer_weighs_scan_trip_count():
    def f(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), ()
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    flops = {}
    for L in (4, 8):
        co = jax.jit(f).lower(
            jax.ShapeDtypeStruct((L, 256, 256), jnp.bfloat16),
            jax.ShapeDtypeStruct((64, 256), jnp.bfloat16)).compile()
        flops[L] = analyze(co.as_text())["flops"]
        dots = L * 2 * 64 * 256 * 256
        assert abs(flops[L] / dots - 1) < 0.2, (L, flops[L], dots)
    assert 1.8 < flops[8] / flops[4] < 2.2


def test_collective_parse_weighted():
    hlo = textwrap.dedent("""\
    HloModule m, is_scheduled=true
    %region_0.1 (arg: (s32[], f32[64,32])) -> (s32[], f32[64,32]) {
      %p = (s32[], f32[64,32]{1,0}) parameter(0)
      %g = f32[64,32]{1,0} get-tuple-element(%p), index=1
      %ar = f32[64,32]{1,0} all-reduce(%g), replica_groups={}, to_apply=%sum.2
      %i = s32[] get-tuple-element(%p), index=0
      ROOT %t = (s32[], f32[64,32]{1,0}) tuple(%i, %ar)
    }
    %sum.2 (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }
    ENTRY %main (x: f32[64,32]) -> f32[64,32] {
      %x = f32[64,32]{1,0} parameter(0)
      %c = s32[] constant(0)
      %tup = (s32[], f32[64,32]{1,0}) tuple(%c, %x)
      %w = (s32[], f32[64,32]{1,0}) while(%tup), condition=%cond.3, body=%region_0.1, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %o = f32[64,32]{1,0} get-tuple-element(%w), index=1
    }
    %cond.3 (p: (s32[], f32[64,32])) -> pred[] {
      %p2 = (s32[], f32[64,32]{1,0}) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %k = s32[] constant(5)
      ROOT %lt = pred[] compare(%i2, %k), direction=LT
    }
    """)
    res = analyze(hlo)
    # all-reduce of 64*32*4 bytes, x2 (RS+AG), x5 trips
    assert res["coll_bytes"]["all-reduce"] == 64 * 32 * 4 * 2 * 5
    assert res["coll_counts"]["all-reduce"] == 5


def test_fractional_subbyte_element_sizes():
    """f4/f6 dtypes count at their packed width (2 elems/byte, 4 per 3
    bytes — matching kernels/pack.py), not one byte each: a 64-element
    f4 all-gather is 32 wire bytes, and the sizes agree with the format
    system's own packed_bytes_per_element."""
    from repro.core import formats as F
    from repro.launch.hlo_analysis import DTYPE_BYTES
    assert DTYPE_BYTES["f4e2m1fn"] == F.FP4E2M1.packed_bytes_per_element
    assert DTYPE_BYTES["f6e2m3fn"] == F.FP6E2M3.packed_bytes_per_element
    assert DTYPE_BYTES["f6e3m2fn"] == F.FP6E3M2.packed_bytes_per_element
    assert DTYPE_BYTES["f8e5m2"] == F.FP8.packed_bytes_per_element
    assert DTYPE_BYTES["u4"] == 0.5
    hlo = textwrap.dedent("""\
    HloModule m
    ENTRY %main (x: f4e2m1fn[8,64]) -> f4e2m1fn[8,64] {
      %x = f4e2m1fn[8,64]{1,0} parameter(0)
      %y = f6e2m3fn[8,64]{1,0} convert(%x)
      %ag = f6e2m3fn[8,64]{1,0} all-gather(%y), dimensions={0}
      ROOT %o = f4e2m1fn[8,64]{1,0} convert(%ag)
    }
    """)
    res = analyze(hlo)
    # the f6 all-gather moves 8*64*0.75 bytes, not 8*64
    assert res["coll_bytes"]["all-gather"] == 8 * 64 * 0.75
    # bytes accessed: two converts (f4 side + f6 side each) plus the
    # all-gather's operand + result, all at fractional element sizes
    f4, f6 = 8 * 64 * 0.5, 8 * 64 * 0.75
    want = (f4 + f6) * 2 + 2 * f6
    assert res["bytes"] == want, (res["bytes"], want)


def test_applicability_matrix():
    skips = []
    for name, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            ok, why = cell_is_applicable(cfg, shape)
            if not ok:
                skips.append((name, sname))
                assert "full-attention" in why
    # exactly the eight non-sub-quadratic archs skip long_500k
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)
    assert not any(a in ("xlstm-125m", "zamba2-7b") for a, _ in skips)


def test_input_specs_shapes():
    cfg = ARCHS["internvl2-76b"]
    sp = input_specs(cfg, SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4096)
    assert sp["aux"]["patches"].shape == (256, 256, 3200)
    spd = input_specs(cfg, SHAPES["decode_32k"])
    assert spd["tok"].shape == (128,)
    assert spd["cache"]["kv"]["k"].shape == (80, 128, 32768, 8, 128)


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """End-to-end: one real dry-run cell at 512 devices in a subprocess."""
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
           "whisper-tiny", "--shape", "train_4k", "--out", str(tmp_path)]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=560,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.load(open(tmp_path / "whisper-tiny_train_4k_pod16x16.json"))
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["flops_per_device"] > 0
    assert rec["memory"]["temp_bytes"] > 0
