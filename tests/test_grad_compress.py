"""Compressed DP gradient wire + optimizer downcast edge cases
(DESIGN.md §13):

* `_quantize_leaf` non-finite guard — inf/NaN gradients must reach the
  loss-scale skip as non-finite output with a *neutral* scale, and the
  error feedback must reset instead of carrying NaN forever;
* `_stochastic_cast` sign-aware next-representable — updates in
  (-ulp, 0) land on -0.0 and must round stochastically toward the first
  negative subnormal (the pre-fix path walked the raw bits into NaN
  space and silently truncated — biased exactly where SR matters);
* multi-step error-feedback convergence on an outlier-heavy tree,
  per-leaf fp8 vs the group-32 MX wire;
* non-finite grads through `compressed_psum_mean` -> `adamw_update`
  skip (state frozen bit-for-bit);
* the EP capacity clamp (`min(cap, t_loc * k)`).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamWConfig, _stochastic_cast, adamw_init, \
    adamw_update
from repro.optim.grad_compress import (_quantize_leaf, compressed_psum_mean,
                                       dp_wire_bytes_per_step,
                                       error_feedback_init)


def _one_dev_mesh():
    from repro.compat import make_mesh
    return make_mesh((1,), ("data",))


def _psum_mean(grads, ef, mesh, axis, mx=None):
    # jit the wire: the eager shard_map path dispatches the packed
    # codec op-by-op and is painfully slow even at test sizes
    return jax.jit(lambda g, e: compressed_psum_mean(
        g, e, mesh, axis, mx=mx))(grads, ef)


# ------------------------------------------------------------------ #
# bugfix 1: non-finite gradients on the compressed wire
# ------------------------------------------------------------------ #

def test_quantize_leaf_nonfinite_keeps_neutral_scale():
    for bad in (jnp.inf, -jnp.inf, jnp.nan):
        g = jnp.array([1.0, -2.0, bad], jnp.float32)
        q, s = _quantize_leaf(g, jnp.float8_e5m2)
        # pre-fix: s = inf (or nan), payload zero-laundered
        assert float(s) == 1.0, (bad, float(s))
        assert not bool(jnp.all(jnp.isfinite(q.astype(jnp.float32))))
    # all-zero and finite leaves keep their semantics
    q0, s0 = _quantize_leaf(jnp.zeros(4, jnp.float32), jnp.float8_e5m2)
    assert float(s0) == 1.0 and not q0.astype(jnp.float32).any()


@pytest.mark.parametrize("mx", [None, "mxfp6e3m2", "mxfp4e2m1"])
def test_nonfinite_propagates_and_ef_resets(mx):
    mesh = _one_dev_mesh()
    grads = {"w": jnp.linspace(-2, 2, 64, jnp.float32).at[3].set(jnp.inf),
             "b": jnp.ones((32,), jnp.float32)}
    ef = error_feedback_init(grads)
    red, new_ef = _psum_mean(grads, ef, mesh, "data", mx=mx)
    # poison reaches the output (the loss-scale/finite-guard skip sees it)
    assert not bool(jnp.all(jnp.isfinite(red["w"])))
    # clean leaves stay clean
    assert bool(jnp.all(jnp.isfinite(red["b"])))
    # the poisoned leaf's error feedback resets to zero — pre-fix it
    # went NaN and poisoned every later step
    assert bool(jnp.all(new_ef["w"] == 0.0))
    assert bool(jnp.all(jnp.isfinite(new_ef["b"])))
    # a finite step after the bad one is healthy again
    red2, ef2 = _psum_mean(
        {"w": jnp.ones((64,), jnp.float32), "b": grads["b"]},
        new_ef, mesh, "data", mx=mx)
    assert bool(jnp.all(jnp.isfinite(red2["w"])))
    assert bool(jnp.all(jnp.isfinite(ef2["w"])))


def test_nonfinite_wire_output_freezes_adamw():
    """compressed wire poison -> finite guard -> adamw skip: the state
    must come back bit-for-bit identical."""
    mesh = _one_dev_mesh()
    params = {"w": jnp.ones((16,), jnp.bfloat16)}
    grads = {"w": jnp.ones((16,), jnp.float32).at[5].set(jnp.nan)}
    cfg = AdamWConfig(lr=1e-2)
    opt = adamw_init(params, cfg)
    red, _ = _psum_mean(grads, error_feedback_init(grads),
                        mesh, "data", mx="mxfp6e3m2")
    finite = bool(jnp.all(jnp.isfinite(red["w"])))
    assert not finite
    newp, new_opt, _ = adamw_update(red, opt, params, cfg,
                                    skip=jnp.array(not finite))
    assert int(new_opt["step"]) == int(opt["step"])
    np.testing.assert_array_equal(np.asarray(newp["w"], np.float32),
                                  np.asarray(params["w"], np.float32))
    np.testing.assert_array_equal(np.asarray(new_opt["m"]["w"]),
                                  np.asarray(opt["m"]["w"]))


# ------------------------------------------------------------------ #
# bugfix 2: stochastic rounding at -0.0
# ------------------------------------------------------------------ #

def test_stochastic_cast_negative_zero_unbiased():
    """Updates in (-ulp, 0) truncate to -0.0 in bf16; SR must still hit
    the first negative subnormal with probability |x|/ulp.  Pre-fix the
    neighbor bits were 0x7FFF (NaN), frac went NaN, and the cast
    silently returned -0.0 every time (bias = the entire update)."""
    x = jnp.full((40000,), -1e-9, jnp.float32)   # |x| << bf16 min subnormal
    out = _stochastic_cast(x, jnp.bfloat16, jax.random.PRNGKey(0))
    outf = np.asarray(out, np.float32)
    assert np.isfinite(outf).all()
    got = float(outf.mean())
    assert abs(got - (-1e-9)) < 0.25e-9, got


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_stochastic_cast_unbiased_lattice(dtype):
    """Mean of many SR casts ~= the exact value across a lattice of
    tiny positive and negative updates around representable points."""
    rng = np.random.default_rng(0)
    base = np.asarray(jnp.asarray(rng.normal(0, 1, 64), dtype)
                      .astype(jnp.float32))
    # one ulp at each point: fp16 has a numpy mirror; bf16's ulp is
    # 2^-8 binade-scaled (plus the subnormal floor for zeros)
    if dtype == jnp.float16:
        step = np.spacing(np.abs(base).astype(np.float16)) \
            .astype(np.float32)
    else:
        step = np.abs(base) * 2.0 ** -8 + 2.0 ** -133
    # sub-ulp offsets in both directions around each representable point
    eps = np.asarray(rng.uniform(-0.4, 0.4, 64), np.float32)
    x = (base + eps * step).astype(np.float32)
    xs = jnp.tile(jnp.asarray(x), (4096, 1))
    out = _stochastic_cast(xs, dtype, jax.random.PRNGKey(1))
    outf = np.asarray(out.astype(jnp.float32))
    assert np.isfinite(outf).all()
    # per-point: the SR mean recovers the sub-ulp offset to ~ulp/20
    np.testing.assert_allclose(outf.mean(0), x, atol=float(step.max()) / 20)


def test_stochastic_cast_preserves_specials_and_exact():
    x = jnp.array([jnp.inf, -jnp.inf, jnp.nan, 0.0, -0.0, 1.5, -1.5],
                  jnp.float32)
    out = np.asarray(_stochastic_cast(x, jnp.bfloat16,
                                      jax.random.PRNGKey(2)), np.float32)
    assert out[0] == np.inf and out[1] == -np.inf and np.isnan(out[2])
    assert out[3] == 0.0 and out[4] == 0.0
    assert out[5] == 1.5 and out[6] == -1.5   # representable: no dither


# ------------------------------------------------------------------ #
# error-feedback convergence: per-leaf fp8 vs group-32 MX
# ------------------------------------------------------------------ #

def test_error_feedback_convergence_outlier_tree():
    """After N steps the accumulated compressed mean tracks the exact
    mean on an outlier-heavy tree, and the group-32 wire's single-step
    error on the non-outlier mass is orders below per-leaf fp8 (whose
    shared scale flushes it)."""
    mesh = _one_dev_mesh()
    rng = np.random.default_rng(0)
    g = rng.normal(0, 1e-3, (8, 256)).astype(np.float32)
    g[0, 0] *= 2.0 ** 36                        # one severe outlier
    grads = {"w": jnp.asarray(g)}
    exact = np.asarray(g, np.float64)

    accs = {}
    single = {}
    for name, mx in (("fp8_leaf", None), ("mxfp6", "mxfp6e3m2")):
        step = jax.jit(lambda g, e, mx=mx: compressed_psum_mean(
            g, e, mesh, "data", mx=mx))
        ef = error_feedback_init(grads)
        acc = np.zeros_like(exact)
        for i in range(40):
            red, ef = step(grads, ef)
            if i == 0:
                single[name] = np.asarray(red["w"], np.float64)
            acc += np.asarray(red["w"], np.float64)
        accs[name] = acc
    target = exact * 40
    for name, acc in accs.items():
        rel = np.abs(acc - target).max() / np.abs(target).max()
        assert rel < 0.02, (name, rel)
    # single-shot: the flushed mass (everything but the hot element)
    mask = np.ones_like(exact, bool)
    mask[0, 0] = False
    err_fp8 = ((single["fp8_leaf"][mask] - exact[mask]) ** 2).mean()
    err_mx = ((single["mxfp6"][mask] - exact[mask]) ** 2).mean()
    # Group-32 scaling confines the outlier's blast radius to its own
    # group, so per-leaf fp8 is >20x worse in MSE on the clean elements.
    # (The full orders-of-magnitude row-NMSE gap is gated in
    # benchmarks/wire_bytes.py's dp_grad section.)
    assert err_mx < err_fp8 / 20, (err_mx, err_fp8)
    # and the packed wire is smaller
    assert (dp_wire_bytes_per_step(grads, mx="mxfp6e3m2")
            < dp_wire_bytes_per_step(grads))


def test_mx_wire_matches_numpy_oracle_single_source():
    """1-device mean == the numpy oracle bit-for-bit on exact-arithmetic
    operands (pow2 group magnitudes x small ints, incl. a NaN-poisoned
    group)."""
    import sys
    sys.path.insert(0, "tests")
    from fuzz import exact_mx_operands
    from repro.core.formats import get_mx_format
    from repro.kernels.ref import compressed_mean_mx_ref

    mesh = _one_dev_mesh()
    for name in ("mxfp8e5m2", "mxfp6e3m2", "mxfp4e2m1"):
        mx = get_mx_format(name)
        rng = np.random.default_rng(3)
        a, _ = exact_mx_operands(rng, 8, 128, 1, mx, span=8)
        grads = {"w": jnp.asarray(a)}
        ef = error_feedback_init(grads)
        red, new_ef = _psum_mean(grads, ef, mesh, "data", mx=name)
        ref, ref_efs = compressed_mean_mx_ref([a], [np.zeros_like(a)], mx=mx)
        np.testing.assert_array_equal(np.asarray(red["w"]), ref, err_msg=name)
        np.testing.assert_array_equal(np.asarray(new_ef["w"]), ref_efs[0],
                                      err_msg=name)


# ------------------------------------------------------------------ #
# bugfix 3: EP capacity clamp
# ------------------------------------------------------------------ #

def test_ep_capacity_clamped_to_token_supply():
    import dataclasses

    from repro.configs import ARCHS
    from repro.models.moe import _ep_capacity

    cfg = dataclasses.replace(ARCHS["granite-moe-3b-a800m"].reduced(),
                              n_experts=8, top_k=2, capacity_factor=100.0)
    # pre-fix: int(2 * 64 * 100 / 8) = 1600 — 12.5x more buffer rows
    # than the 128 routes that exist
    assert _ep_capacity(cfg, 64, 8) == 64 * 2
    # unclamped regime unchanged
    cfg2 = dataclasses.replace(cfg, capacity_factor=1.0)
    assert _ep_capacity(cfg2, 64, 8) == max(8, int(2 * 64 * 1.0 / 8))


def test_moe_einsum_aux_metrics_dict():
    import dataclasses

    from repro.configs import ARCHS
    from repro.core.policy import get_policy
    from repro.models import moe as MOE

    cfg = dataclasses.replace(ARCHS["granite-moe-3b-a800m"].reduced(),
                              n_experts=4, top_k=2, capacity_factor=0.5)
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    y, aux = MOE.moe_ffn(x, p, cfg, get_policy("bf16"))
    assert y.shape == x.shape
    assert set(aux) == {"loss", "drop_frac", "capacity"}
    # cf=0.5 under-provisions: drops must be realized and surfaced
    assert 0.0 < float(aux["drop_frac"]) < 1.0, float(aux["drop_frac"])
