"""Tests for the fused ExSdotp/ExVsum/Vsum semantics (paper §III-B/C)."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core import exsdotp as X

RNG = np.random.default_rng(42)

SRC_DST = [("fp8", "fp16"), ("fp8alt", "fp16"), ("fp8", "fp16alt"),
           ("fp8alt", "fp16alt"), ("fp16", "fp32"), ("fp16alt", "fp32")]


def _rand(fmt, n, scale=1.0):
    return F.quantize_np(RNG.normal(0, scale, n), fmt)


# ----------------------------------------------------------------- oracle --

def test_nonassociativity_worked_example():
    """Paper §III-B: |a| >> |c|, b = -a: (a+b)+c = c but a+(b+c) may be 0.

    The fused three-term add must return c; a cascade of two adds
    (inner first) loses it.
    """
    # fp16 values: a = 2048, b = -2048, c = 0.25.  b + c rounds to b in fp16.
    a, b, c = 2048.0, -2048.0, 0.25
    fused = X.vsum_np(a, b, c, "fp16")
    assert fused == 0.25
    inner = F.quantize_np(np.float64(b + c), F.FP16)   # = -2048 (c absorbed)
    cascade = F.quantize_np(np.float64(a + inner), F.FP16)
    assert cascade == 0.0                              # catastrophic loss


@pytest.mark.parametrize("src,dst", SRC_DST)
def test_exsdotp_single_rounding_matches_f64(src, dst):
    """For well-scaled inputs the fused result == RNE_dst of the f64 value."""
    n = 512
    a, b, c, d = (_rand(src, n) for _ in range(4))
    e = _rand(dst, n, 4.0)
    ours = X.exsdotp_np(a, b, c, d, e, src, dst)
    golden = F.quantize_np(a * b + c * d + e, dst)  # exact in f64 here
    np.testing.assert_array_equal(ours, golden)


def test_exsdotp_beats_cascade_on_cancellation():
    """Construct the paper's precision-loss case: products cancel exactly."""
    src, dst = "fp8", "fp16"
    # a*b = 4, c*d = -4, e tiny: cascade computes 4 + RNE(-4 + e).
    a, b, c, d = 2.0, 2.0, -2.0, 2.0
    e = 2.0 ** -14  # small enough that (-4 + e) rounds back to -4 in fp16
    fused = X.exsdotp_np(a, b, c, d, e, src, dst)[()]
    casc = X.exfma_cascade_np(a, b, c, d, e, src, dst)[()]
    assert fused == e       # exact-zero recovery keeps the accumulator
    assert casc == 0.0      # two roundings lose it


@pytest.mark.parametrize("src,dst", SRC_DST)
def test_exvsum_is_exsdotp_with_ones(src, dst):
    n = 256
    a, c = _rand(src, n), _rand(src, n)
    e = _rand(dst, n, 4.0)
    np.testing.assert_array_equal(
        X.exvsum_np(a, c, e, src, dst),
        X.exsdotp_np(a, np.ones(n), c, np.ones(n), e, src, dst))


def test_special_values():
    nan = X.exsdotp_np(np.nan, 1.0, 1.0, 1.0, 1.0, "fp8")
    assert math.isnan(nan[()])
    inf = X.exsdotp_np(448.0, 448.0, 448.0, 448.0, 60000.0, "fp8alt", "fp16")
    assert math.isinf(inf[()])
    opp = X.exvsum_np(np.inf, -np.inf, 1.0, "fp16", "fp32")
    assert math.isnan(opp[()])


# ------------------------------------------------------ jax vs oracle ------

@pytest.mark.parametrize("src,dst", SRC_DST)
def test_jax_matches_oracle(src, dst):
    n = 2048
    a, b, c, d = (_rand(src, n) for _ in range(4))
    e = _rand(dst, n, 4.0)
    ours = np.asarray(X.exsdotp(*map(jnp.asarray, (a, b, c, d, e)), src, dst),
                      np.float64)
    oracle = X.exsdotp_np(a, b, c, d, e, src, dst)
    # TwoSum compensation is exact except for ties in the correction term;
    # demand exactness on >=99.9% and <=1 ulp everywhere.
    exact = np.mean(ours == oracle)
    assert exact >= 0.999, f"only {exact:.4%} bit-exact"
    fdst = F.get_format(dst)
    ulp = np.abs(oracle) * 2.0 ** (-fdst.man_bits) + fdst.min_subnormal
    np.testing.assert_array_compare(lambda x, y: x <= y,
                                    np.abs(ours - oracle), ulp)


def test_jax_vsum_matches_oracle():
    n = 1024
    a, c, e = (_rand("fp16", n, 8.0) for _ in range(3))
    ours = np.asarray(X.vsum(jnp.asarray(a), jnp.asarray(c), jnp.asarray(e), "fp16"))
    oracle = vs = X.vsum_np(a, c, e, "fp16")
    assert np.mean(ours == oracle) >= 0.999


# ------------------------------------------------------- property-based ----

@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_property_fused_single_rounding(seed):
    """Invariant: fused result == correctly-rounded exact sum (any inputs)."""
    rng = np.random.default_rng(seed)
    src, dst = ("fp8", "fp16") if seed % 2 else ("fp16", "fp32")
    scale = 4.0 ** rng.integers(-4, 5)
    a, b, c, d = (F.quantize_np(rng.normal(0, scale), src) for _ in range(4))
    e = F.quantize_np(rng.normal(0, scale * scale), dst)
    got = X.exsdotp_np(a, b, c, d, e, src, dst)[()]
    # golden: exact dyadic sum rounded once (recomputed independently)
    exact = X._exact_3sum_round((float(a) * float(b), float(c) * float(d),
                                 float(e)), F.get_format(dst))
    assert got == exact or (math.isnan(got) and math.isnan(exact))


@pytest.mark.parametrize("src", ["fp8", "fp8alt"])
def test_fused_beats_cascade_in_aggregate(src):
    """Paper Table IV: ExSdotp chains are *consistently* (in aggregate) more
    accurate than ExFMA chains. Per-draw either may win (error cancellation),
    so compare mean |relative error| over many chains.
    """
    rng = np.random.default_rng(7)
    errs_f, errs_c = [], []
    for _ in range(60):
        a = F.quantize_np(rng.normal(0, 1, 128), src)
        b = F.quantize_np(rng.normal(0, 1, 128), src)
        exact = float(np.dot(a, b))
        # normalize by the accumulation scale, not the (possibly cancelled)
        # exact value, so single ill-conditioned draws don't dominate
        denom = float(np.sum(np.abs(a * b))) + 1e-9
        fused = X.exsdotp_chain_np(a, b, src)
        casc = X.exfma_chain_np(a, b, src)
        errs_f.append(abs(fused - exact) / denom)
        errs_c.append(abs(casc - exact) / denom)
    assert np.mean(errs_f) <= np.mean(errs_c) * 1.001
    assert np.median(errs_f) <= np.median(errs_c) * 1.001
