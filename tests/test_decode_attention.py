"""Decode attention over the serving KV cache (DESIGN.md §12):
oracle-backed harness, mirroring test_mx_attention.py.

1. the numpy oracle (``ref.mx_decode_attention_ref``) is pinned to the
   carrier decode reference on losslessly-quantizable operands;
2. the packed Pallas kernel (interpret mode) and the xla ops branch
   must match the oracle **bit for bit** on
   ``fuzz.exact_decode_operands`` — per-sequence base offsets, NaN
   garbage beyond the live prefix, and poison (NaN-scale) groups
   inside it — for every serving MX format;
3. the base-offset carry-skip doubles as a *page-skip*: KV tiles past
   ``(iq+1)·bq + lens[b]`` never execute (``debug_visited``), and
   skipping is bitwise neutral;
4. structural garbage masking: non-finite trash in dead cache slots
   (stale payloads of a freed page) cannot leak into live rows.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import fuzz
from repro.core import formats as F
from repro.kernels import ops, ref
from repro.kernels.decode_attention import (decode_attention_pallas,
                                            mx_decode_attention_pallas)

POLICY_FORMATS = ["mxfp8e4m3", "mxfp6e2m3", "mxfp4e2m1"]

#: (bh, s, t, hd, lens) — s=1 is steady-state decode, s>1 block prefill
SHAPES = [
    (2, 4, 64, 64, [3, 17]),
    (2, 1, 64, 64, [1, 40]),      # single-row decode tiles (bq = 1)
    (3, 8, 128, 32, [5, 64, 100]),
]


def _quantized(k, v, name):
    kp, ks8 = ops.mx_quantize_kv(jnp.asarray(k), name, impl="xla")
    vp, vs8 = ops.mx_quantize_kv(jnp.asarray(v), name, impl="xla")
    return kp, ks8, vp, vs8


def _run_all_impls(q, k, v, lens, name):
    """(oracle, interpret, xla) outputs for one format."""
    want = ref.mx_decode_attention_ref(q, k, v, lens, mx_k=name)
    kp, ks8, vp, vs8 = _quantized(k, v, name)
    qj, lj = jnp.asarray(q), jnp.asarray(lens)
    got_i = np.asarray(ops.mx_decode_attention_packed(
        qj, kp, ks8, vp, vs8, lj, mx_k=name, impl="pallas_interpret"))
    got_x = np.asarray(ops.mx_decode_attention_packed(
        qj, kp, ks8, vp, vs8, lj, mx_k=name, impl="xla"))
    return want, got_i, got_x


# ------------------------------------------------------------- oracle ----

def test_oracle_is_carrier_decode_on_lossless_operands():
    """k/v from {0, ±64, ±128, ±256} survive every MX quantizer exactly,
    so the quantized oracle must equal the unquantized decode reference
    (garbage excluded structurally by both)."""
    rng = np.random.default_rng(0)
    q, k, v, lens = fuzz.exact_decode_operands(rng, 2, 4, 64, 64, [3, 17],
                                               garbage=False)
    plain = np.asarray(ref.decode_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(lens)))
    for name in F.MX_FORMATS:
        want = ref.mx_decode_attention_ref(q, k, v, lens, mx_k=name)
        np.testing.assert_array_equal(want, plain, err_msg=name)


# ------------------------------------------------- kernel bit-exactness --

@pytest.mark.parametrize("name", POLICY_FORMATS)
def test_kernel_bit_exact_vs_oracle(name):
    """Interpret kernel and xla branch vs the numpy oracle, bit for bit
    — garbage NaN beyond every sequence's live prefix included."""
    for i, (bh, s, t, hd, lens) in enumerate(SHAPES):
        rng = np.random.default_rng(100 + i)
        q, k, v, lens = fuzz.exact_decode_operands(rng, bh, s, t, hd, lens)
        want, got_i, got_x = _run_all_impls(q, k, v, lens, name)
        assert np.isfinite(want).all()   # garbage must not leak
        np.testing.assert_array_equal(got_i, want,
                                      err_msg=f"interp {(bh, s, t, hd)}")
        np.testing.assert_array_equal(got_x, want,
                                      err_msg=f"xla {(bh, s, t, hd)}")


def test_carrier_kernel_bit_exact_vs_ref():
    """The carrier-page kernel (bf16 fallback) against the jnp decode
    reference on the same exact operands."""
    for i, (bh, s, t, hd, lens) in enumerate(SHAPES):
        rng = np.random.default_rng(200 + i)
        q, k, v, lens = fuzz.exact_decode_operands(rng, bh, s, t, hd, lens)
        qj, kj, vj, lj = map(jnp.asarray, (q, k, v, lens))
        want = np.asarray(ref.decode_attention_ref(qj, kj, vj, lj))
        got = np.asarray(ops.decode_attention(qj, kj, vj, lj,
                                              impl="pallas_interpret"))
        np.testing.assert_array_equal(got, want, err_msg=str((bh, s, t, hd)))


@pytest.mark.parametrize("name", POLICY_FORMATS)
def test_kernel_poison_group_propagates(name):
    """A NaN-scale v group *inside the live prefix* poisons exactly its
    32 output columns for every query row — identically in kernel and
    oracle — while garbage NaN *outside* it stays fully masked."""
    rng = np.random.default_rng(7)
    q, k, v, lens = fuzz.exact_decode_operands(rng, 2, 4, 64, 64, [3, 17],
                                               specials=True)
    want, got_i, got_x = _run_all_impls(q, k, v, lens, name)
    nan_w = np.isnan(want)
    assert nan_w[:, :, :32].all() and not nan_w[:, :, 32:].any()
    for got, tag in ((got_i, "interp"), (got_x, "xla")):
        np.testing.assert_array_equal(np.isnan(got), nan_w, err_msg=tag)
        np.testing.assert_array_equal(got[~nan_w], want[~nan_w],
                                      err_msg=tag)


def test_garbage_slots_cannot_leak():
    """Freed-page trash: with every dead slot NaN (both k and v), all
    outputs stay finite — the masking is structural (0-fill before the
    dot), not a softmax-weight zero, which 0·NaN would defeat."""
    rng = np.random.default_rng(11)
    q, k, v, lens = fuzz.exact_decode_operands(rng, 2, 4, 64, 64, [1, 9])
    assert np.isnan(k).any() and np.isnan(v).any()   # trash present
    for name in POLICY_FORMATS:
        want, got_i, got_x = _run_all_impls(q, k, v, lens, name)
        assert np.isfinite(got_i).all() and np.isfinite(got_x).all(), name
    got = np.asarray(decode_attention_pallas(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(lens),
        block_q=4, block_k=32, interpret=True))
    assert np.isfinite(got).all()


def test_kernel_tolerance_on_arbitrary_data():
    """Random data: same quantization in kernel and oracle, so drift is
    f32 summation order only."""
    rng = np.random.default_rng(13)
    bh, s, t, hd = 2, 4, 64, 64
    q = rng.normal(0, 1, (bh, s, hd)).astype(np.float32)
    k = rng.normal(0, 1, (bh, t, hd)).astype(np.float32)
    v = rng.normal(0, 1, (bh, t, hd)).astype(np.float32)
    lens = np.asarray([3, 17], np.int32)
    for name in POLICY_FORMATS:
        want, got_i, got_x = _run_all_impls(q, k, v, lens, name)
        np.testing.assert_allclose(got_i, want, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got_x, want, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- page-skip ---

def test_page_skip_visits_only_live_tiles():
    """The per-sequence base offset feeds the carry-skip: a KV tile
    executes iff ``kk·bk < (iq+1)·bq + lens[b]`` — so a short sequence
    skips the pages it never filled."""
    rng = np.random.default_rng(17)
    bh, s, t, hd, bq, bk = 2, 4, 128, 32, 2, 32
    lens = np.asarray([3, 90], np.int32)
    q, k, v, lens = fuzz.exact_decode_operands(rng, bh, s, t, hd, lens)
    _, vis = decode_attention_pallas(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(lens),
        block_q=bq, block_k=bk, debug_visited=True, interpret=True)
    iq = np.arange(s // bq)[:, None]
    kk = np.arange(t // bk)[None, :]
    live = (kk * bk < (iq + 1) * bq + lens[:, None, None]).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(vis), live)
    # the short sequence actually skips pages the long one visits
    assert np.asarray(vis)[0].sum() < np.asarray(vis)[1].sum()


def test_page_skip_is_bitwise_neutral():
    rng = np.random.default_rng(19)
    q, k, v, lens = fuzz.exact_decode_operands(rng, 2, 4, 128, 32,
                                               [3, 90])
    for name in POLICY_FORMATS[:1] + [None]:
        if name is None:
            run = lambda skip: decode_attention_pallas(
                jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                jnp.asarray(lens), block_q=2, block_k=32,
                skip_masked=skip, interpret=True)
        else:
            kp, ks8, vp, vs8 = _quantized(k, v, name)
            run = lambda skip: mx_decode_attention_pallas(
                jnp.asarray(q), kp, ks8, vp, vs8, jnp.asarray(lens),
                mx_k=name, block_q=2, block_k=32, skip_masked=skip,
                interpret=True)
        np.testing.assert_array_equal(np.asarray(run(True)),
                                      np.asarray(run(False)),
                                      err_msg=str(name))


# ------------------------------------------------------- ops-layer API ---

def test_decode_attention_blocks_tiling():
    """Unlike attention_blocks, decode tiling never fails: q tiles have
    floor 1 (S=1 steady-state decode), KV tiles floor 8."""
    assert ops.decode_attention_blocks(1, 64) == (1, 64)
    assert ops.decode_attention_blocks(8, 128) == (8, 128)
    assert ops.decode_attention_blocks(7, 48) == (1, 16)   # 7 -> q tile 1
    assert ops.decode_attention_blocks(12, 12) == (4, 1)   # no 8-divisor


def test_packed_kernel_checks_payload_shapes():
    q = jnp.zeros((1, 4, 64), jnp.float32)
    lens = jnp.ones((1,), jnp.int32)
    kp, ks8 = ops.mx_quantize_kv(jnp.zeros((1, 32, 64)), "mxfp6e2m3",
                                 impl="xla")
    with pytest.raises(AssertionError):  # payload packed for another width
        mx_decode_attention_pallas(q, kp, ks8, kp, ks8, lens,
                                   mx_k="mxfp8e4m3", block_q=4,
                                   block_k=32, interpret=True)
