"""Packed sub-byte payload storage (DESIGN.md §9).

Three layers:

1. the bit-packing itself, exhaustively: every FP4 byte pattern (256)
   and every FP6 3-byte lane (2^24) round-trips through
   unpack -> pack unchanged, and every code vector through
   pack -> unpack;
2. the JAX codecs (``formats.encode``/``decode``, jnp pack/unpack,
   ``e8m0_encode``/``decode``) are bit-identical to their numpy
   oracles on all codes and on random values;
3. the wired path: ``mx_quantize(packed=True)`` payloads measure the
   real sub-byte footprint (FP4: 2 elements/byte, FP6: 4 per 3 bytes),
   unpack losslessly, and ``mx_gemm_packed`` is bit-identical to
   ``ops.mx_gemm`` on the same operands.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats as F
from repro.kernels import ops
from repro.kernels import pack as P

MX_NAMES = list(F.MX_FORMATS)


# ----------------------------------------------- exhaustive round trips --

def test_fp4_all_256_byte_patterns_round_trip():
    b = np.arange(256, dtype=np.uint8)
    codes = P.unpack4_np(b)
    assert codes.shape == (512,) and codes.max() < 16
    np.testing.assert_array_equal(P.pack4_np(codes), b)
    # and the jnp path, bit-identical
    np.testing.assert_array_equal(
        np.asarray(P.pack4(P.unpack4(jnp.asarray(b)))), b)


def test_fp4_all_code_pairs_round_trip():
    c = np.stack(np.meshgrid(np.arange(16), np.arange(16)),
                 -1).reshape(-1, 2).astype(np.uint8)
    np.testing.assert_array_equal(P.unpack4_np(P.pack4_np(c)), c)


@pytest.mark.exhaustive
def test_fp6_all_3byte_lanes_round_trip():
    """Every possible 3-byte lane (2^24 of them): unpack to four 6-bit
    codes and repack — identity, so no bit of the lane is lost or
    aliased.  ``exhaustive``: these sweeps run in the nightly CI leg;
    tier-1 covers the boundary-lane sample (tests/test_codec.py via
    ``fuzz.fp6_lanes``)."""
    v = np.arange(2 ** 24, dtype=np.uint32)
    lanes = np.stack([v & 0xFF, (v >> 8) & 0xFF, (v >> 16) & 0xFF],
                     -1).astype(np.uint8)
    codes = P.unpack6_np(lanes)
    assert codes.shape == (2 ** 24, 4) and codes.max() < 64
    np.testing.assert_array_equal(P.pack6_np(codes), lanes)


@pytest.mark.exhaustive
def test_fp6_all_code_quads_round_trip():
    c = np.arange(2 ** 24, dtype=np.uint32)
    quads = np.stack([(c >> (6 * i)) & 0x3F for i in range(4)],
                     -1).astype(np.uint8)
    np.testing.assert_array_equal(P.unpack6_np(P.pack6_np(quads)), quads)


def test_jnp_pack_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    c4 = rng.integers(0, 16, (5, 7, 64)).astype(np.uint8)
    c6 = rng.integers(0, 64, (5, 7, 64)).astype(np.uint8)
    np.testing.assert_array_equal(P.pack4_np(c4),
                                  np.asarray(P.pack4(jnp.asarray(c4))))
    np.testing.assert_array_equal(P.pack6_np(c6),
                                  np.asarray(P.pack6(jnp.asarray(c6))))
    np.testing.assert_array_equal(
        P.unpack6_np(P.pack6_np(c6)),
        np.asarray(P.unpack6(P.pack6(jnp.asarray(c6)))))


# ------------------------------------------------------------ jnp codecs --

@pytest.mark.parametrize("name", ["fp8", "fp8alt", "fp6e2m3", "fp6e3m2",
                                  "fp4e2m1"])
def test_jax_encode_decode_matches_numpy(name):
    fmt = F.get_format(name)
    codes = np.arange(1 << fmt.width, dtype=np.uint8)
    vn = F.decode_np(codes, fmt)
    vj = np.asarray(F.decode(jnp.asarray(codes), fmt), np.float64)
    np.testing.assert_array_equal(np.isnan(vn), np.isnan(vj))
    np.testing.assert_array_equal(vn[~np.isnan(vn)], vj[~np.isnan(vj)])
    # encode round-trips every decodable value to its own code (NaN
    # codes collapse to the canonical quiet NaN in both impls)
    ej = np.asarray(F.encode(jnp.asarray(vj, jnp.float32), fmt))
    np.testing.assert_array_equal(F.encode_np(vn, fmt).astype(np.uint8), ej)
    np.testing.assert_array_equal(codes[~np.isnan(vn)], ej[~np.isnan(vn)])
    # arbitrary (non-representable) values quantize-and-encode the same
    rng = np.random.default_rng(1)
    x = np.concatenate([rng.normal(0, fmt.max_normal / 2, 2048),
                        [0.0, -0.0, np.inf, -np.inf, np.nan,
                         fmt.max_normal * 4, fmt.min_subnormal / 3]])
    x = x.astype(np.float32)
    np.testing.assert_array_equal(
        F.encode_np(x, fmt).astype(np.uint8),
        np.asarray(F.encode(jnp.asarray(x), fmt)))


def test_e8m0_jnp_codecs_match_numpy():
    s = np.asarray([2.0 ** -126, 0.25, 0.5, 1.0, 2.0, 2.0 ** 127, np.nan],
                   np.float32)
    np.testing.assert_array_equal(F.e8m0_encode_np(s),
                                  np.asarray(F.e8m0_encode(jnp.asarray(s))))
    codes = np.arange(256, dtype=np.uint8)
    dn = F.e8m0_decode_np(codes)
    dj = np.asarray(F.e8m0_decode(jnp.asarray(codes)), np.float64)
    np.testing.assert_array_equal(np.isnan(dn), np.isnan(dj))
    np.testing.assert_array_equal(dn[:255], dj[:255])


def test_packed_bytes_per_element():
    assert F.FP4E2M1.packed_bytes_per_element == 0.5
    assert F.FP6E2M3.packed_bytes_per_element == 0.75
    assert F.FP8.packed_bytes_per_element == 1.0
    assert F.FP4E2M1.pack_align == 2 and F.FP6E2M3.pack_align == 4
    assert F.FP8.pack_align == 1
    # MX adds one E8M0 byte per group of 32
    assert F.MXFP4E2M1.packed_bytes_per_element == 0.5 + 1 / 32
    assert P.packed_length(64, 4) == 32 and P.packed_length(64, 6) == 48


# ------------------------------------------------------- MX wired path ----

@pytest.mark.parametrize("name", MX_NAMES)
def test_mx_quantize_packed_is_lossless(name):
    mx = F.get_mx_format(name)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 8, (3, 16, 64)), jnp.float32)
    q, s = ops.mx_quantize(x, name, impl="xla")
    p, s8 = ops.mx_quantize(x, name, impl="xla", packed=True)
    assert p.dtype == jnp.uint8 and s8.dtype == jnp.uint8
    # the honest footprint: width/8 bytes per element, 1 byte per group
    assert p.shape == (3, 16, 64 * mx.elem.width // 8)
    assert s8.shape == (3, 16, 64 // mx.group)
    np.testing.assert_array_equal(np.asarray(ops.mx_unpack(p, name)),
                                  np.asarray(q))
    sd = np.asarray(F.e8m0_decode(s8), np.float64)
    sn = np.asarray(s, np.float64)
    np.testing.assert_array_equal(np.isnan(sn), np.isnan(sd))
    np.testing.assert_array_equal(sn[~np.isnan(sn)], sd[~np.isnan(sd)])


@pytest.mark.parametrize("name", MX_NAMES)
def test_mx_gemm_packed_bit_exact_vs_mx_gemm(name):
    """Storage-path GEMM == value-path GEMM bit for bit on arbitrary
    float data: pack/unpack is lossless and the math after it is the
    same (NaN rows positionally equal via array_equal)."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(0, 4, (2, 16, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 4, (64, 24)), jnp.float32)
    want = ops.mx_gemm(a, b, mx_a=name, impl="xla")
    ap, sa8 = ops.mx_quantize(a, name, impl="xla", packed=True)
    bp, sb8 = ops.mx_quantize(b.T, name, impl="xla", packed=True)
    got = ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a=name)
    assert got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_mx_gemm_packed_mixed_formats_and_poison():
    """E4M3 × E5M2 pairing from packed storage, with a non-finite group:
    the NaN travels as the 0xFF scale byte and poisons its row."""
    rng = np.random.default_rng(4)
    a = rng.normal(0, 2, (8, 64)).astype(np.float32)
    a[1, 5] = np.inf
    aj = jnp.asarray(a)
    b = jnp.asarray(rng.normal(0, 2, (64, 16)), jnp.float32)
    want = ops.mx_gemm(aj, b, mx_a="mxfp8e4m3", mx_b="mxfp8e5m2",
                       impl="xla")
    ap, sa8 = ops.mx_quantize(aj, "mxfp8e4m3", impl="xla", packed=True)
    bp, sb8 = ops.mx_quantize(b.T, "mxfp8e5m2", impl="xla", packed=True)
    assert int(np.asarray(sa8)[1, 0]) == F.E8M0_NAN
    got = ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a="mxfp8e4m3",
                             mx_b="mxfp8e5m2")
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    assert np.isnan(np.asarray(got)[1]).all()
