"""MX-quantized flash attention (DESIGN.md §11): oracle-backed harness.

Three layers, mirroring test_mx.py:

1. the numpy oracle (``ref.mx_flash_attention_ref``) is pinned to the
   unquantized reference on losslessly-quantizable operands;
2. the packed Pallas kernel (interpret mode) and the xla ops branch must
   match the oracle **bit for bit** on ``fuzz.exact_attention_operands``
   — data constructed so every online-softmax rescale is exactly 0 or 1
   and every f32 sum is exact — for every supported MX format, poison
   (NaN-scale) groups included; arbitrary data is held to f32
   summation-order tolerance.  The causal carry-skip is regression-
   tested for bitwise neutrality and for actually skipping (the
   ``debug_visited`` interpret-mode counter);
3. model routing: ``attention()`` under the MX policies runs the packed
   kernel (and only then), a real train step under ``mxfp8`` routes and
   produces finite grads, and the packed-footprint accounting exposes
   the KV bytes the pipeline saves.
"""
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import fuzz
from repro.core import formats as F
from repro.core.policy import get_policy
from repro.kernels import ops, ref
from repro.kernels.flash_attention import (flash_attention_pallas,
                                           mx_flash_attention_pallas)

#: one element format per training policy — the tier-1 sweep
POLICY_FORMATS = ["mxfp8e4m3", "mxfp6e2m3", "mxfp4e2m1"]
ALL_FORMATS = list(F.MX_FORMATS)

TIER1_SHAPES = [(2, 64, 64, 64), (1, 64, 128, 64), (3, 40, 40, 64)]


def _run_all_impls(q, k, v, name, causal):
    """(oracle, interpret, xla) outputs for one format/mask config."""
    want = ref.mx_flash_attention_ref(q, k, v, mx_k=name, causal=causal)
    qj, kj, vj = jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)
    got_i = ops.mx_flash_attention(qj, kj, vj, mx_k=name, causal=causal,
                                   impl="pallas_interpret")
    got_x = ops.mx_flash_attention(qj, kj, vj, mx_k=name, causal=causal,
                                   impl="xla")
    return want, np.asarray(got_i), np.asarray(got_x)


# ------------------------------------------------------------- oracle ----

@pytest.mark.parametrize("causal", [True, False], ids=["causal", "full"])
def test_oracle_is_plain_softmax_on_lossless_operands(causal):
    """k/v from {0, ±64, ±128, ±256} survive every MX quantizer exactly,
    so the quantized oracle must equal the unquantized reference."""
    rng = np.random.default_rng(0)
    q, k, v = fuzz.exact_attention_operands(rng, 2, 64, 64, 64,
                                            causal=causal)
    plain = np.asarray(ref.flash_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal))
    for name in ALL_FORMATS:
        want = ref.mx_flash_attention_ref(q, k, v, mx_k=name, causal=causal)
        np.testing.assert_array_equal(want, plain, err_msg=name)


# ------------------------------------------------- kernel bit-exactness --

@pytest.mark.parametrize("causal", [True, False], ids=["causal", "full"])
@pytest.mark.parametrize("name", POLICY_FORMATS)
def test_kernel_bit_exact_vs_oracle(name, causal):
    for i, (bh, s, t, hd) in enumerate(TIER1_SHAPES):
        rng = np.random.default_rng(100 + i)
        q, k, v = fuzz.exact_attention_operands(rng, bh, s, t, hd,
                                                causal=causal)
        want, got_i, got_x = _run_all_impls(q, k, v, name, causal)
        np.testing.assert_array_equal(got_i, want,
                                      err_msg=f"interp {(bh, s, t, hd)}")
        np.testing.assert_array_equal(got_x, want,
                                      err_msg=f"xla {(bh, s, t, hd)}")


@pytest.mark.exhaustive
@pytest.mark.parametrize("causal", [True, False], ids=["causal", "full"])
@pytest.mark.parametrize("name", ALL_FORMATS)
def test_kernel_bit_exact_vs_oracle_all_formats(name, causal):
    """Nightly: every format × every harness shape (incl. hd=128)."""
    for i, (bh, s, t, hd) in enumerate(fuzz.attention_shapes()):
        rng = np.random.default_rng(200 + i)
        q, k, v = fuzz.exact_attention_operands(rng, bh, s, t, hd,
                                                causal=causal)
        want, got_i, got_x = _run_all_impls(q, k, v, name, causal)
        np.testing.assert_array_equal(got_i, want,
                                      err_msg=f"interp {(bh, s, t, hd)}")
        np.testing.assert_array_equal(got_x, want,
                                      err_msg=f"xla {(bh, s, t, hd)}")


@pytest.mark.parametrize("name", POLICY_FORMATS)
def test_kernel_poison_group_propagates(name):
    """A NaN-scale v group poisons exactly its 32 output columns, for
    every query row, identically in kernel and oracle.  causal=False:
    a partially-masked causal tile still streams its masked columns,
    where kernel 0·NaN and the oracle's structural exclusion of masked
    keys legitimately differ (see the oracle docstring)."""
    rng = np.random.default_rng(7)
    q, k, v = fuzz.exact_attention_operands(rng, 2, 64, 64, 64,
                                            causal=False, specials=True)
    want, got_i, got_x = _run_all_impls(q, k, v, name, causal=False)
    nan_w = np.isnan(want)
    # poisoned group 0 of hd -> columns [0, 32) NaN on every row, only
    assert nan_w[:, :, :32].all() and not nan_w[:, :, 32:].any()
    for got, tag in ((got_i, "interp"), (got_x, "xla")):
        np.testing.assert_array_equal(np.isnan(got), nan_w, err_msg=tag)
        np.testing.assert_array_equal(got[~nan_w], want[~nan_w],
                                      err_msg=tag)


@pytest.mark.parametrize("name", POLICY_FORMATS)
def test_kernel_tolerance_on_arbitrary_data(name):
    """Random data: quantization is identical across impls (same oracle
    math), so the only drift is f32 summation order in the sweep."""
    rng = np.random.default_rng(11)
    for bh, s, t, hd in TIER1_SHAPES:
        q = rng.normal(0, 1, (bh, s, hd)).astype(np.float32)
        k = rng.normal(0, 1, (bh, t, hd)).astype(np.float32)
        v = rng.normal(0, 1, (bh, t, hd)).astype(np.float32)
        want, got_i, got_x = _run_all_impls(q, k, v, name, causal=True)
        np.testing.assert_allclose(got_i, want, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got_x, want, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- carry-skip --

@pytest.mark.parametrize("shape,blocks", [
    ((2, 64, 64, 32), (32, 32)),    # S = T, square tiles
    ((2, 64, 64, 32), (16, 32)),    # bq < bk: skip boundary mid-row-tile
    ((2, 64, 64, 32), (32, 16)),    # bq > bk: several skipped col tiles
    ((1, 128, 64, 32), (32, 32)),   # S > T
    ((1, 64, 128, 32), (32, 32)),   # S < T: whole right half skippable
], ids=str)
def test_carry_skip_is_bitwise_neutral(shape, blocks):
    """Causal output is identical with the skip on or off — a fully
    masked tile's update is a structural no-op — on arbitrary finite
    data (no exactness construction needed)."""
    bh, s, t, hd = shape
    bq, bk = blocks
    rng = np.random.default_rng(13)
    q = jnp.asarray(rng.normal(0, 1, (bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (bh, t, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (bh, t, hd)), jnp.float32)
    on = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                block_k=bk, skip_masked=True,
                                interpret=True)
    off = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                 block_k=bk, skip_masked=False,
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))


def test_carry_skip_visits_only_live_tiles():
    """The interpret-mode tile counter: a causal (iq, kk) tile executes
    the sweep body iff its first column can reach its last row
    (kk·bk < (iq+1)·bq); non-causal and skip-off sweeps visit all."""
    rng = np.random.default_rng(17)
    bh, s, t, hd, bq, bk = 2, 64, 64, 32, 16, 32
    q = jnp.asarray(rng.normal(0, 1, (bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (bh, t, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (bh, t, hd)), jnp.float32)
    iq = np.arange(s // bq)[:, None]
    kk = np.arange(t // bk)[None, :]
    live = (kk * bk < (iq + 1) * bq).astype(np.int32)
    assert 0 < live.sum() < live.size  # the case actually exercises both

    _, vis = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                    block_k=bk, debug_visited=True,
                                    interpret=True)
    np.testing.assert_array_equal(
        np.asarray(vis), np.broadcast_to(live, (bh, *live.shape)))
    for kwargs in ({"causal": False}, {"causal": True,
                                       "skip_masked": False}):
        _, vis = flash_attention_pallas(q, k, v, block_q=bq, block_k=bk,
                                        debug_visited=True, interpret=True,
                                        **kwargs)
        assert np.asarray(vis).all(), kwargs


def test_carry_skip_in_packed_kernel():
    """The MX kernel shares the shell: same visit pattern, and skip
    on/off stays bitwise equal through the packed decode path."""
    rng = np.random.default_rng(19)
    q, k, v = fuzz.exact_attention_operands(rng, 1, 64, 64, 64)
    kp, ks8 = ops.mx_quantize_kv(jnp.asarray(k), "mxfp8e4m3", impl="xla")
    vp, vs8 = ops.mx_quantize_kv(jnp.asarray(v), "mxfp8e4m3", impl="xla")
    args = (jnp.asarray(q), kp, ks8, vp, vs8)
    on, vis = mx_flash_attention_pallas(*args, mx_k="mxfp8e4m3",
                                        block_q=16, block_k=32,
                                        debug_visited=True, interpret=True)
    off = mx_flash_attention_pallas(*args, mx_k="mxfp8e4m3", block_q=16,
                                    block_k=32, skip_masked=False,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
    iq, kk = np.arange(4)[:, None], np.arange(2)[None, :]
    np.testing.assert_array_equal(
        np.asarray(vis)[0], (kk * 32 < (iq + 1) * 16).astype(np.int32))


# ------------------------------------------------------- ops-layer API ---

def test_attention_blocks_tiling():
    assert ops.attention_blocks(64, 64) == (64, 64)
    assert ops.attention_blocks(256, 128) == (128, 128)
    assert ops.attention_blocks(96, 40) == (32, 8)
    assert ops.attention_blocks(33, 64) is None   # S not an 8-multiple
    assert ops.attention_blocks(64, 12) is None   # T not an 8-multiple


def test_mx_quantize_kv_requires_whole_groups():
    with pytest.raises(AssertionError):
        ops.mx_quantize_kv(jnp.zeros((1, 8, 48)), "mxfp8e4m3", impl="xla")


def test_packed_kernel_checks_payload_shapes():
    q = jnp.zeros((1, 32, 64), jnp.float32)
    kp, ks8 = ops.mx_quantize_kv(jnp.zeros((1, 32, 64)), "mxfp6e2m3",
                                 impl="xla")
    with pytest.raises(AssertionError):  # payload packed for another width
        mx_flash_attention_pallas(q, kp, ks8, kp, ks8, mx_k="mxfp8e4m3",
                                  block_q=32, block_k=32, interpret=True)


def test_packed_kv_is_the_honest_footprint():
    """The payload the sweep streams is width/8 bytes per element plus
    one scale byte per group — the bytes the wire benchmark gates."""
    kv = jnp.asarray(np.random.default_rng(3).normal(0, 1, (2, 64, 64)),
                     jnp.float32)
    for name in POLICY_FORMATS:
        mx = F.get_mx_format(name)
        p, s8 = ops.mx_quantize_kv(kv, name, impl="xla")
        assert p.dtype == jnp.uint8 and s8.dtype == jnp.uint8
        assert p.shape == (2, 64, 64 * mx.elem.width // 8)
        assert s8.shape == (2, 64, 64 // 32)
        total = p.size + s8.size
        assert total == int(2 * 64 * 64 * mx.packed_bytes_per_element)


# ---------------------------------------------------------- model layer --

def _tiny_attn_setup(dtype=jnp.float32):
    from repro.models import layers

    class Cfg:
        d_model = 64
        n_heads = 2
        n_kv_heads = 1
        head_dim_eff = 32
        qkv_bias = False
        causal = True
        pos_embed = "rope"
        rope_theta = 10000.0
        attn_q_chunk = 32
        norm = "rmsnorm"
        norm_eps = 1e-5

    cfg = Cfg()
    p = layers.init_attention(jax.random.key(0), cfg, dtype)
    x = jax.random.normal(jax.random.key(1), (2, 64, 64), dtype)
    return layers, cfg, p, x


@pytest.mark.parametrize("policy", ["mxfp8", "mxfp6", "mxfp4"])
def test_attention_routes_mx_policies_through_packed_kernel(policy):
    layers, cfg, p, x = _tiny_attn_setup()
    pol = get_policy(policy)
    calls = []
    orig = ops.mx_flash_attention_packed

    def spy(*a, **kw):
        calls.append(F.get_mx_format(kw["mx_k"]).name)
        return orig(*a, **kw)

    with mock.patch.object(ops, "mx_flash_attention_packed",
                           side_effect=spy):
        def loss(p):
            out, _ = layers.attention(x, p, cfg, pol,
                                      positions=jnp.arange(64), impl="xla")
            return jnp.sum(out.astype(jnp.float32) ** 2)

        l, g = jax.value_and_grad(loss)(p)
    assert calls == [pol.mx_attn_name], calls  # fwd routes; bwd recomputes
    assert np.isfinite(float(l))
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_attention_does_not_route_non_mx_or_decode():
    layers, cfg, p, x = _tiny_attn_setup()
    with mock.patch.object(ops, "mx_flash_attention_packed") as spy:
        for pol in ("bf16", "hfp8", "hfp8_block", "fp32"):
            layers.attention(x, p, cfg, get_policy(pol),
                             positions=jnp.arange(64), impl="xla")
        # decode: cache present -> positional masking the kernel lacks
        cache = layers.init_kv_cache(cfg, 2, 128, jnp.float32)
        layers.attention(x[:, :1], p, cfg, get_policy("mxfp8"),
                         positions=jnp.arange(1), kv_cache=cache,
                         impl="xla")
        # misaligned sequence (not an 8-multiple)
        layers.attention(x[:, :33], p, cfg, get_policy("mxfp8"),
                         positions=jnp.arange(33), impl="xla")
    assert not spy.called


def test_attention_mx_output_tracks_unquantized():
    """Routed output stays close to the exact-softmax path on the same
    projections — the quantization is the only difference."""
    layers, cfg, p, x = _tiny_attn_setup()
    out_mx, _ = layers.attention(x, p, cfg, get_policy("mxfp8"),
                                 positions=jnp.arange(64), impl="xla")
    with mock.patch.object(layers, "_mx_attention_applicable",
                           return_value=False):
        out_ref, _ = layers.attention(x, p, cfg, get_policy("mxfp8"),
                                      positions=jnp.arange(64), impl="xla")
    err = np.abs(np.asarray(out_mx - out_ref, np.float32))
    scale = np.abs(np.asarray(out_ref, np.float32)).max()
    assert err.max() <= 0.1 * scale, (err.max(), scale)


def test_train_step_routes_attention_under_mxfp8():
    """A real train step (the train_lm tiny path, scaled down): the
    packed attention kernel runs inside the jitted step and the loss/
    grads stay finite."""
    from repro.configs.base import ModelConfig
    from repro.models import build_model
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import make_train_state, make_train_step

    cfg = ModelConfig(name="lm-attn-test", family="dense", n_layers=1,
                      d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
                      vocab_size=128, head_dim=32, policy_name="mxfp8",
                      attn_q_chunk=64)
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=4)
    state = make_train_state(model, jax.random.key(0), opt)
    step = make_train_step(model, opt, impl="xla")
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, 128, (2, 64)))

    calls = []
    orig = ops.mx_flash_attention_packed

    def spy(*a, **kw):
        calls.append(F.get_mx_format(kw["mx_k"]).name)
        return orig(*a, **kw)

    with mock.patch.object(ops, "mx_flash_attention_packed",
                           side_effect=spy):
        state, metrics = step(state, tokens)
    # tracing may visit attention more than once (e.g. vjp re-trace);
    # what matters is that every visit routed the packed kernel
    assert calls and set(calls) == {"mxfp8e4m3"}, calls
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


def test_packed_footprint_reports_attn_kv():
    from repro.launch.hlo_analysis import (format_packed_footprint,
                                           policy_packed_footprint)
    for policy in ("mxfp8", "mxfp6", "mxfp4"):
        pol = get_policy(policy)
        fp = policy_packed_footprint(policy)
        want = F.get_mx_format(pol.mx_attn_name).packed_bytes_per_element
        assert fp["operands"]["attn_kv"] == want, policy
        assert "attn_kv" in format_packed_footprint(policy)
    # non-MX: attention runs at carrier precision
    assert policy_packed_footprint("hfp8")["operands"]["attn_kv"] == 2.0
    assert policy_packed_footprint("fp32")["operands"]["attn_kv"] == 4.0
