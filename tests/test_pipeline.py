"""Pipeline-parallel (GPipe) correctness: 4-stage pipeline == sequential.

Needs 4 devices -> runs in a subprocess with forced host device count.
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import make_mesh
    from repro.parallel.pipeline import gpipe

    mesh = make_mesh((4,), ("stage",))
    rng = np.random.default_rng(0)
    S, M, mb, d = 4, 6, 8, 32
    # each stage: y = tanh(x @ w + b)
    ws = jnp.asarray(rng.normal(0, 0.5, (S, d, d)), jnp.float32)
    bs = jnp.asarray(rng.normal(0, 0.1, (S, d)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (M, mb, d)), jnp.float32)

    def layer(p, xmb):
        w, b = p
        return jnp.tanh(xmb @ w + b)

    out = jax.jit(lambda pp, xx: gpipe(layer, pp, xx, mesh=mesh,
                                       axis="stage"))((ws, bs), x)
    # sequential reference
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ ws[s] + bs[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("GPIPE_OK")
""")


def test_gpipe_matches_sequential():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=300, env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "GPIPE_OK" in r.stdout
