"""Per-architecture smoke tests: reduced config, one forward + one
backward step on CPU; assert output shapes and finiteness (no NaNs).
The FULL configs are exercised only by the dry-run (launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model

ARCH_IDS = sorted(ARCHS)


def _aux_for(cfg, batch, rng):
    if cfg.family == "encdec":
        return {"frames": jnp.asarray(
            rng.normal(0, 1, (batch, cfg.enc_seq, cfg.d_model)), jnp.bfloat16)}
    if cfg.family == "vlm":
        return {"patches": jnp.asarray(
            rng.normal(0, 1, (batch, cfg.n_patches, cfg.frontend_dim)),
            jnp.bfloat16)}
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.key(0))
    batch, seq = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)))
    aux = _aux_for(cfg, batch, rng)
    logits, aux_loss = jax.jit(
        lambda p, t, a: model.apply(p, t, aux=a))(params, tokens, aux)
    assert logits.shape == (batch, seq, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux_loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_grad_smoke(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.key(1))
    batch, seq = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)))
    aux = _aux_for(cfg, batch, rng)

    def loss_fn(p):
        return model.loss(p, tokens, aux=aux, remat=True)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat, _ = jax.tree_util.tree_flatten(grads)
    assert flat, "no gradients produced"
    for g in flat:
        assert np.isfinite(np.asarray(g, np.float32)).all()
    # embedding gradient must be nonzero (whole graph is connected)
    gnorm = sum(jnp.sum(jnp.abs(g.astype(jnp.float32))) for g in flat)
    assert float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_smoke(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(2)
    params = model.init(jax.random.key(2))
    batch, max_len = 2, 32
    cache = model.init_cache(batch, max_len)
    if cfg.family == "encdec":
        frames = jnp.asarray(rng.normal(0, 1, (batch, cfg.enc_seq,
                                                cfg.d_model)), jnp.bfloat16)
        cache = model.prefill_cache(params, frames, cache)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch,)))
    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c))
    logits, cache = step(params, tok, cache)
    assert logits.shape == (batch, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # a second step must advance the cache index
    logits2, cache2 = step(params, tok, cache)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_decode_matches_prefill_dense():
    """Decode with KV cache must reproduce teacher-forced prefill logits."""
    cfg = ARCHS["deepseek-7b"].reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, policy_name="bf16")  # avoid quant noise
    model = build_model(cfg)
    rng = np.random.default_rng(3)
    params = model.init(jax.random.key(3))
    batch, seq = 2, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)))
    full_logits, _ = jax.jit(lambda p, t: model.apply(p, t))(params, tokens)
    cache = model.init_cache(batch, seq)
    outs = []
    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c))
    for i in range(seq):
        lg, cache = step(params, tokens[:, i], cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-2, atol=2e-2)
