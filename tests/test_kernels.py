"""Pallas kernel validation: interpret-mode vs pure-jnp oracles.

Per instructions: sweep shapes/dtypes and assert_allclose against ref.py.
Bit-exactness is asserted on integer-valued inputs (fp32 accumulation is
then exact in both implementations regardless of summation order).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(3)

SRC_DTYPES = [jnp.float8_e5m2, jnp.float8_e4m3, jnp.float16, jnp.bfloat16]
SHAPES = [(8, 16, 8), (128, 128, 128), (64, 256, 32), (100, 130, 50),
          (1, 512, 1), (256, 64, 512)]


@pytest.mark.parametrize("src", SRC_DTYPES, ids=lambda d: d.__name__)
@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_gemm_matches_ref(src, shape):
    m, k, n = shape
    a = jnp.asarray(RNG.normal(0, 1, (m, k)), src)
    b = jnp.asarray(RNG.normal(0, 1, (k, n)), src)
    out = ops.exsdotp_gemm(a, b, 0.5, out_dtype=jnp.float32,
                           impl="pallas_interpret", blocks=(8, 8, 16))
    want = ref.exsdotp_gemm_ref(a, b, 0.5, out_dtype=jnp.float32)
    # fp32 accumulation order differs (tiled partial sums vs full-K dot):
    # worst-case relative drift ~ K * 2^-24.
    tol = max(k * 2.0 ** -24, 1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol * np.sqrt(k))


@pytest.mark.parametrize("src", SRC_DTYPES, ids=lambda d: d.__name__)
@pytest.mark.parametrize("out_dtype", [jnp.float16, jnp.bfloat16, jnp.float32],
                         ids=lambda d: d.__name__)
def test_gemm_bit_exact_on_integer_inputs(src, out_dtype):
    """Integer-valued operands make fp32 accumulation exact -> bit equality,
    including the single final downcast (the ExSdotp rounding step)."""
    m, k, n = 48, 96, 32
    a = jnp.asarray(RNG.integers(-4, 5, (m, k)), src)
    b = jnp.asarray(RNG.integers(-4, 5, (k, n)), src)
    out = ops.exsdotp_gemm(a, b, 1.0, out_dtype=out_dtype,
                           impl="pallas_interpret", blocks=(16, 16, 32))
    want = ref.exsdotp_gemm_ref(a, b, 1.0, out_dtype=out_dtype)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(want, np.float32))


def test_gemm_expanding_accumulation_beats_dst_accumulation():
    """The point of the unit (paper Fig. 9): wide accumulation wins.

    Accumulating fp8 products in fp16 (non-expanding chain) drifts; the
    kernel's fp32 VMEM accumulator with one final rounding stays within
    1 fp16 ulp of the exact result.
    """
    k = 4096
    a = jnp.asarray(RNG.normal(0, 1, (1, k)), jnp.float8_e4m3)
    b = jnp.asarray(RNG.normal(0, 1, (k, 1)), jnp.float8_e4m3)
    out = ops.exsdotp_gemm(a, b, 1.0, out_dtype=jnp.float16,
                           impl="pallas_interpret", blocks=(1, 1, 64))
    exact = (np.asarray(a, np.float64) @ np.asarray(b, np.float64)).item()
    # naive fp16 running accumulation
    acc = np.float16(0)
    af = np.asarray(a, np.float32)[0]
    bf = np.asarray(b, np.float32)[:, 0]
    for i in range(k):
        acc = np.float16(acc + np.float16(af[i] * bf[i]))
    ulp = abs(exact) * 2.0 ** -10
    assert abs(float(np.asarray(out, np.float32)[0, 0]) - exact) <= ulp
    assert abs(float(acc) - exact) > ulp  # the naive chain actually drifts


@pytest.mark.parametrize("q_dtype", [jnp.float8_e5m2, jnp.float8_e4m3],
                         ids=lambda d: d.__name__)
@pytest.mark.parametrize("shape", [(128, 128), (256, 384), (100, 70)], ids=str)
def test_quant_blockwise_matches_ref(q_dtype, shape):
    x = jnp.asarray(RNG.normal(0, 5, shape), jnp.float32)
    q, s = ops.quantize_blockwise(x, q_dtype, block_m=32, block_n=32,
                                  impl="pallas_interpret")
    qr, sr = ops.quantize_blockwise(x, q_dtype, block_m=32, block_n=32,
                                    impl="xla")
    np.testing.assert_array_equal(np.asarray(q, np.float32),
                                  np.asarray(qr, np.float32))
    # scale may differ by 1 f32 ulp (XLA may fuse /s as *rcp(s))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=3e-7)


@pytest.mark.parametrize("shape", [(100, 70), (1, 1), (33, 129), (8, 200)],
                         ids=str)
def test_quant_blockwise_pallas_ragged_direct(shape):
    """Regression: ``quant_blockwise_pallas`` used to assert divisibility
    and rely on the caller to pad; it now pads ragged M/N itself (like
    ``ops`` does for the GEMMs) and slices the payload back."""
    from repro.kernels.quant import quant_blockwise_pallas
    m, n = shape
    x = jnp.asarray(RNG.normal(0, 5, shape), jnp.float32)
    q, s = quant_blockwise_pallas(x, q_dtype=jnp.float8_e4m3, block_m=32,
                                  block_n=32, interpret=True)
    assert q.shape == shape
    assert s.shape == ((m + 31) // 32, (n + 31) // 32)
    qr, sr = ops.quantize_blockwise(x, jnp.float8_e4m3, block_m=32,
                                    block_n=32, impl="xla")
    np.testing.assert_array_equal(np.asarray(q, np.float32),
                                  np.asarray(qr, np.float32))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=3e-7)


def test_quant_roundtrip_error_bound():
    """|x - dequant(quant(x))| <= 2^-m * blockmax for every block."""
    x = jnp.asarray(RNG.normal(0, 3, (256, 256)), jnp.float32)
    for q_dtype, man in [(jnp.float8_e5m2, 2), (jnp.float8_e4m3, 3)]:
        q, s = ops.quantize_blockwise(x, q_dtype, block_m=64, block_n=64,
                                      impl="pallas_interpret")
        back = ops.dequantize_blockwise(q, s, block_m=64, block_n=64)
        err = np.abs(np.asarray(back) - np.asarray(x))
        bmax = np.abs(np.asarray(x)).reshape(4, 64, 4, 64).max((1, 3))
        bound = np.repeat(np.repeat(bmax, 64, 0), 64, 1) * 2.0 ** (-man) * 1.01
        assert (err <= bound).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 5), st.integers(1, 5), st.integers(1, 5),
       st.integers(0, 2**31 - 1))
def test_property_gemm_any_shape(mb, kb, nb, seed):
    """Property: kernel == oracle for random block-multiple shapes."""
    rng = np.random.default_rng(seed)
    m, k, n = 8 * mb, 16 * kb, 8 * nb
    a = jnp.asarray(rng.integers(-3, 4, (m, k)), jnp.float8_e4m3)
    b = jnp.asarray(rng.integers(-3, 4, (k, n)), jnp.float8_e5m2)
    out = ops.exsdotp_gemm(a, b, 1.0, out_dtype=jnp.float32,
                           impl="pallas_interpret", blocks=(8, 8, 16))
    want = ref.exsdotp_gemm_ref(a, b, 1.0, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ------------------------------------------------------ flash attention ---

@pytest.mark.parametrize("blocks", [(32, 32), (64, 64), (128, 128),
                                    (32, 128), (128, 32)], ids=str)
@pytest.mark.parametrize("causal", [True, False], ids=["causal", "full"])
@pytest.mark.parametrize("shape", [(2, 128, 16), (1, 256, 64)], ids=str)
def test_flash_attention_matches_ref(causal, shape, blocks):
    """Block shapes up to the full 128 tile, square and rectangular —
    the online-softmax recurrence must not care how the sweep tiles."""
    from repro.kernels.flash_attention import flash_attention_pallas
    bh, s, hd = shape
    bq, bk = blocks
    q = jnp.asarray(RNG.normal(0, 1, (bh, s, hd)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(0, 1, (bh, s, hd)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(0, 1, (bh, s, hd)), jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_attention_rejects_misaligned_lengths():
    """The kernels assert S/T divide the blocks instead of silently
    padding (a padded length would corrupt the positional mask)."""
    from repro.kernels.flash_attention import flash_attention_pallas
    q = jnp.zeros((1, 48, 16), jnp.float32)
    kv = jnp.zeros((1, 48, 16), jnp.float32)
    with pytest.raises(AssertionError):
        flash_attention_pallas(q, kv, kv, block_q=32, block_k=32,
                               interpret=True)
    with pytest.raises(AssertionError):  # T misaligned, S fine
        flash_attention_pallas(q[:, :32], kv, kv, block_q=32, block_k=32,
                               interpret=True)


def test_flash_attention_cross_lengths():
    """S != T (cross attention / cached decode windows)."""
    from repro.kernels.flash_attention import flash_attention_pallas
    q = jnp.asarray(RNG.normal(0, 1, (2, 32, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (2, 128, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (2, 128, 16)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=False, block_q=32,
                                 block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
