"""Autotune machinery (DESIGN.md §14).

Four layers:

1. the sweep-and-cache core with *injected* bench stubs: a miss sweeps
   every candidate and persists the winner, a hit never re-times, the
   memo survives being dropped (re-read from disk), a version bump or a
   stale entry outside the candidate space invalidates, and failing
   candidates are skipped (all-fail falls back to the first candidate,
   unpersisted);
2. candidate legality by construction: every generated GEMM tile
   respects the sublane/lane floors, the codec ``lane_unit`` and the
   MX group, stays under the VMEM budget when a cost model is given,
   and attention tiles divide S/T exactly; the packed-GEMM layout axis
   only offers double buffering when the K loop has ≥ 2 tiles, and
   blockscale candidates only subdivide the fixed scale grid;
3. ``tiles="auto"`` numerics: with a deliberately non-default winner
   seeded into a scratch cache, the tuned path is *bitwise* equal to
   the static default on exact-arithmetic operands for all five MX
   formats (GEMM) and for the packed flash sweep — the §14 contract
   that tuning can never change results;
4. the double-buffered manual-DMA K-loop is bitwise equal to the
   grid-pipelined schedule for each codec lane class, and every
   "DESIGN.md §N" / "EXPERIMENTS.md §X" reference in src/ and
   benchmarks/ resolves to a real heading.
"""
import json
import os
import re

import jax.numpy as jnp
import numpy as np
import pytest

import fuzz
from repro.core import formats as F
from repro.kernels import autotune, ops
from repro.kernels.blockscale_gemm import mx_gemm_packed_pallas
from repro.kernels.codec import get_codec

MX_NAMES = list(F.MX_FORMATS)


@pytest.fixture
def tune_dir(tmp_path, monkeypatch):
    """Scratch cache dir + no env sweeping; memo cleared on both sides."""
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_TUNE_SWEEP", raising=False)
    autotune.clear_memo()
    yield str(tmp_path)
    autotune.clear_memo()


def _ceil_mult(x, u):
    return max(u, x + (-x) % u)


# ----------------------------------------------- sweep-and-cache core --

def test_autotune_sweeps_persists_then_hits(tune_dir):
    calls = []

    def bench(tl):
        calls.append(tl)
        return float(sum(tl))

    cands = [(32,), (8,), (16,)]
    res = autotune.autotune("toy", "k1", cands, bench, iters=3, warmup=1)
    assert res.source == "swept" and res.tiles == (8,)
    assert len(calls) == len(cands) * (3 + 1)   # warmup + iters each
    with open(os.path.join(tune_dir, "toy.json")) as f:
        data = json.load(f)
    assert data["version"] == autotune.CACHE_VERSION
    assert data["entries"]["k1"]["tiles"] == [8]

    calls.clear()
    res2 = autotune.autotune("toy", "k1", cands, bench)
    assert res2.source == "cache" and res2.tiles == (8,)
    assert not calls                             # a hit never re-times

    autotune.clear_memo()                        # force the disk re-read
    res3 = autotune.autotune("toy", "k1", cands, bench)
    assert res3.source == "cache" and res3.tiles == (8,)
    assert not calls


def test_cache_version_mismatch_invalidates(tune_dir):
    path = os.path.join(tune_dir, "toy.json")
    with open(path, "w") as f:
        json.dump({"version": autotune.CACHE_VERSION - 1,
                   "entries": {"k": {"tiles": [8], "us": 1.0}}}, f)
    autotune.clear_memo()
    assert autotune.peek("toy", "k") is None


def test_stale_entry_outside_candidates_resweeps(tune_dir):
    autotune.autotune("toy", "k", [(64,)], lambda tl: 1.0)
    calls = []

    def bench(tl):
        calls.append(tl)
        return float(sum(tl))

    res = autotune.autotune("toy", "k", [(8,), (16,)], bench)
    assert res.source == "swept" and res.tiles == (8,) and calls


def test_failing_candidates_skipped_all_fail_defaults(tune_dir):
    def bench(tl):
        if tl == (8,):
            raise RuntimeError("illegal tile")
        return float(sum(tl))

    res = autotune.autotune("toy", "k2", [(8,), (16,)], bench)
    assert res.source == "swept" and res.tiles == (16,)

    def bomb(tl):
        raise RuntimeError("no candidate runs")

    res = autotune.autotune("toy", "k3", [(8,), (16,)], bomb)
    assert res.source == "default" and res.tiles == (8,)
    assert autotune.peek("toy", "k3") is None    # failures never persist


# ----------------------------------------------- candidate legality ----

@pytest.mark.parametrize("name", MX_NAMES)
def test_gemm_candidates_respect_floors(name):
    mx = F.get_mx_format(name)
    c = get_codec(mx)
    m, n, k = 40, 200, 4 * c.lane_unit
    cands = autotune.gemm_tile_candidates(
        m, n, k, group=mx.group, lane_units=(c.lane_unit,))
    assert cands
    for bm, bn, bk in cands:
        assert bm % 8 == 0 and bn % 128 == 0
        assert bk % 128 == 0 and bk % mx.group == 0
        assert bk % c.lane_unit == 0             # packed byte run legal
        assert bm <= _ceil_mult(m, 8)            # ≤ minimally padded dims
        assert bn <= _ceil_mult(n, 128)
        assert bk <= _ceil_mult(k, c.lane_unit)


def test_gemm_candidates_respect_vmem_budget():
    def cost(tl):
        bm, bn, bk = tl
        return 64 * (bm * bk + bk * bn + bm * bn)

    free = autotune.gemm_tile_candidates(4096, 4096, 4096)
    kept = autotune.gemm_tile_candidates(4096, 4096, 4096,
                                         vmem_bytes_fn=cost)
    assert kept and set(kept) < set(free)        # pruning removed some
    for tl in kept:
        assert cost(tl) <= autotune.VMEM_BUDGET


def test_attention_candidates_divide_exactly():
    for s, t in [(40, 96), (128, 128), (1, 8), (96, 64)]:
        lo = autotune.attention_tile_candidates(s, t, q_floor=1)
        assert lo
        for bq, bk in lo:
            assert s % bq == 0 and t % bk == 0 and bk >= 8
        for bq, bk in autotune.attention_tile_candidates(s, t):
            assert bq >= 8                       # train/prefill floor


def test_packed_layout_axis_needs_two_k_tiles(tune_dir):
    seen = []

    def bench(tl):
        seen.append(tuple(tl))
        return float(len(seen))

    autotune.gemm_packed_tiles(128, 128, 256, "mxfp8e4m3", None,
                               impl="pallas_interpret", bench_fn=bench)
    cands = set(seen)
    assert any(db for *_, db in cands)
    for bm, bn, bk, db in cands:
        if db:                                   # ≥ 2 K tiles to overlap
            assert _ceil_mult(256, bk) // bk >= 2
    # the single-K-tile shape (bk = 256) must appear grid-pipelined only
    assert (128, 128, 256, 0) in cands and (128, 128, 256, 1) not in cands


def test_blockscale_candidates_subdivide_scale_grid(tune_dir):
    seen = []

    def bench(tl):
        seen.append(tuple(tl))
        return float(sum(tl))

    sm, sn, sk = 128, 128, 256
    (bm, bn, bk), res = autotune.blockscale_tiles(
        256, 256, 512, (sm, sn, sk), jnp.float8_e4m3fn, jnp.float8_e5m2,
        impl="pallas_interpret", sweep=True, bench_fn=bench)
    assert res.source == "swept"
    for tm, tn, tk in set(seen):                 # scale grid never moves
        assert sm % tm == 0 and sn % tn == 0 and sk % tk == 0
    assert (bm, bn, bk) == min(set(seen), key=sum)


# ----------------------------------------------- tiles="auto" numerics --

@pytest.mark.parametrize("name", MX_NAMES)
def test_tiles_auto_bit_exact_gemm(tune_dir, name):
    mx = F.get_mx_format(name)
    m, k, n = 16, 256, 128
    # seed a deliberately non-default winner: the stub prefers the
    # smallest tile and the double-buffered layout when offered
    tiles, db, res = autotune.gemm_packed_tiles(
        m, n, k, mx, mx, impl="pallas_interpret", sweep=True,
        bench_fn=lambda tl: float(tl[0] + tl[1] + tl[2] - tl[3]))
    assert res.source == "swept"
    assert tiles[0] == 8                         # static heuristic picks 16

    rng = np.random.default_rng(7)
    a, b = fuzz.exact_mx_operands(rng, m, k, n, mx)
    ap, sa8 = ops.mx_quantize(jnp.asarray(a), mx, packed=True)
    bp, sb8 = ops.mx_quantize(jnp.asarray(b.T), mx, packed=True)
    base = ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a=mx,
                              impl="pallas_interpret")
    auto = ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a=mx,
                              impl="pallas_interpret", tiles="auto")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(auto))


def test_tiles_auto_bit_exact_mx_flash(tune_dir):
    mx = F.get_mx_format("mxfp8e4m3")
    bh, s, t, hd = 2, 64, 64, 64
    tiles, res = autotune.attention_tiles(
        "mx_flash", bh, s, t, hd, fmt_k=mx, causal=True,
        impl="pallas_interpret", sweep=True,
        bench_fn=lambda tl: float(tl[0] + tl[1]))
    assert res.source == "swept"
    assert tiles == (8, 8)                       # static pick is (64, 64)

    rng = np.random.default_rng(3)
    q, k, v = fuzz.exact_attention_operands(rng, bh, s, t, hd)
    kp, ks8 = ops.mx_quantize_kv(jnp.asarray(k), mx)
    vp, vs8 = ops.mx_quantize_kv(jnp.asarray(v), mx)
    base = ops.mx_flash_attention_packed(
        jnp.asarray(q), kp, ks8, vp, vs8, mx_k=mx, impl="pallas_interpret")
    auto = ops.mx_flash_attention_packed(
        jnp.asarray(q), kp, ks8, vp, vs8, mx_k=mx, impl="pallas_interpret",
        tiles="auto")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(auto))


def test_tiles_auto_cache_miss_matches_static(tune_dir):
    """CPU CI with an empty cache: auto falls back to the static
    heuristic (no sweep, no timing) — byte-identical, zero surprise."""
    mx = F.get_mx_format("mxfp4e2m1")
    rng = np.random.default_rng(5)
    a, b = fuzz.exact_mx_operands(rng, 16, 256, 128, mx)
    ap, sa8 = ops.mx_quantize(jnp.asarray(a), mx, packed=True)
    bp, sb8 = ops.mx_quantize(jnp.asarray(b.T), mx, packed=True)
    base = ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a=mx,
                              impl="pallas_interpret")
    auto = ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a=mx,
                              impl="pallas_interpret", tiles="auto")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(auto))
    assert not os.path.exists(os.path.join(tune_dir, "mx_gemm_packed.json"))


# ----------------------------------------------- double buffering ------

@pytest.mark.parametrize("name", ["mxfp8e4m3", "mxfp6e2m3", "mxfp4e2m1"])
def test_double_buffer_bitwise_equal(name):
    mx = F.get_mx_format(name)
    c = get_codec(mx)
    m, n, k = 16, 128, 2 * c.lane_unit           # ≥ 2 K tiles to overlap
    rng = np.random.default_rng(11)
    a, b = fuzz.exact_mx_operands(rng, m, k, n, mx)
    ap, sa8 = ops.mx_quantize(jnp.asarray(a), mx, packed=True)
    bp, sb8 = ops.mx_quantize(jnp.asarray(b.T), mx, packed=True)
    sae8 = jnp.repeat(sa8, mx.group, axis=-1)
    sbe8 = jnp.repeat(sb8, mx.group, axis=-1)
    kw = dict(mx_a=mx, mx_b=mx, block_m=8, block_n=128,
              block_k=c.lane_unit, interpret=True)
    grid = mx_gemm_packed_pallas(ap, bp, sae8, sbe8,
                                 double_buffer=False, **kw)
    dbuf = mx_gemm_packed_pallas(ap, bp, sae8, sbe8,
                                 double_buffer=True, **kw)
    # same accumulation order — bitwise, NaN poison included
    np.testing.assert_array_equal(np.asarray(grid), np.asarray(dbuf))


# ----------------------------------------------- § references resolve --

def test_design_section_references_resolve():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(repo, "DESIGN.md")) as f:
        sections = set(re.findall(r"^## §(\d+)", f.read(), re.M))
    with open(os.path.join(repo, "EXPERIMENTS.md")) as f:
        exp_heads = {h.split()[0]
                     for h in re.findall(r"^## (.+)$", f.read(), re.M)}
    bad = []
    for root in ("src", "benchmarks"):
        for dirpath, _, files in os.walk(os.path.join(repo, root)):
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                with open(os.path.join(dirpath, fn)) as f:
                    text = f.read()
                for run in re.findall(
                        r"DESIGN\.md\s+(§\d+(?:\s*/\s*§\d+)*)", text):
                    for num in re.findall(r"§(\d+)", run):
                        if num not in sections:
                            bad.append((fn, f"DESIGN.md §{num}"))
                for nm in re.findall(r"EXPERIMENTS\.md\s+§([\w*]+)", text):
                    if nm not in exp_heads:
                        bad.append((fn, f"EXPERIMENTS.md §{nm}"))
    assert not bad, f"dangling section references: {bad}"
