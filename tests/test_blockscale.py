"""Block-scaled ExSdotp GEMM: fused Pallas kernel vs the vectorized
dyadic oracle, and the accuracy regression per-block vs per-tensor.

Bit-exactness strategy (mirrors test_kernels.py): data is constructed so
every intermediate — the in-kernel cast, the per-block pow2 rescale, the
fp32 accumulation — is exact; then the kernel, the jnp ref and the
``exsdotp_np``-chain oracle must agree bit for bit, in any summation
order.  Per-block dynamic range is made *extreme* (tiles spanning 2^±12)
— exactly the regime where per-tensor scaling collapses.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import exsdotp as X
from repro.core import formats as F
from repro.core.scaling import BlockScaleConfig, compute_block_scales
from repro.kernels import ops, ref

RNG = np.random.default_rng(11)

FMTS = [("fp8", jnp.float8_e5m2), ("fp8alt", jnp.float8_e4m3)]


def _exact_operands(rng, m, k, n, bs, emax=12):
    """Integer-grid operands with per-(row/col)-block pow2 magnitudes.

    Each tile's amax is pinned to 7 so the pow2 scale is uniform along
    K; products and partial sums then stay exact in fp32 (see module
    docstring), while tiles span 2^-emax .. 2^emax.
    """
    na = rng.integers(-7, 8, (m, k)).astype(np.float64)
    nb = rng.integers(-7, 8, (k, n)).astype(np.float64)
    na[::bs, ::bs] = 7.0
    nb[::bs, ::bs] = 7.0
    ra = 2.0 ** rng.integers(-emax, emax + 1, (m // bs, 1))
    rc = 2.0 ** rng.integers(-emax, emax + 1, (1, n // bs))
    a = na * np.repeat(ra, bs, 0)
    b = nb * np.repeat(rc, bs, 1)
    return a, b


def _oracle_blockscale(a, b, sa, sb, src_fmt, bm, bn, bk, out_fmt):
    """Numpy oracle: per-block quantize → vectorized ExSdotp-chain GEMM →
    pow2 dequant → accumulate → one rounding into out_fmt."""
    m, k = a.shape
    _, n = b.shape
    out = np.zeros((m, n))
    for i in range(m // bm):
        for j in range(n // bn):
            acc = np.zeros((bm, bn))
            for t in range(k // bk):
                ab = a[i * bm:(i + 1) * bm, t * bk:(t + 1) * bk] / sa[i, t]
                bb = b[t * bk:(t + 1) * bk, j * bn:(j + 1) * bn] / sb[t, j]
                part = X.exsdotp_gemm_np(ab, bb, src_fmt, "fp32")
                acc = acc + part * (sa[i, t] * sb[t, j])
            out[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn] = acc
    return F.quantize_np(out, out_fmt)


@pytest.mark.parametrize("fmt,q_dtype", FMTS, ids=[f[0] for f in FMTS])
@pytest.mark.parametrize("out_fmt,out_dtype",
                         [("fp32", jnp.float32)], ids=["f32out"])
def test_fused_blockscale_bit_exact_vs_oracle(fmt, q_dtype, out_fmt,
                                              out_dtype):
    m, k, n, bs = 64, 48, 32, 16
    a, b = _exact_operands(RNG, m, k, n, bs)
    aj = jnp.asarray(a, jnp.float32)
    bj = jnp.asarray(b, jnp.float32)
    cfg = BlockScaleConfig(block_m=bs, block_n=bs, block_k=bs)
    sa = np.asarray(compute_block_scales(aj, bs, bs, q_dtype))
    sb = np.asarray(compute_block_scales(bj, bs, bs, q_dtype))
    assert (np.log2(sa) == np.round(np.log2(sa))).all()  # pow2 scales
    want = _oracle_blockscale(a, b, sa, sb, fmt, bs, bs, bs, out_fmt)
    got = ops.blockscale_gemm(aj, bj, q_dtype_a=q_dtype, cfg=cfg,
                              out_dtype=out_dtype, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(got, np.float64), want)


@pytest.mark.parametrize("fmt,q_dtype", FMTS, ids=[f[0] for f in FMTS])
def test_fused_blockscale_bit_exact_narrow_out(fmt, q_dtype):
    """Milder dynamic range so bf16 output doesn't overflow: the final
    downcast (the unit's one rounding) must also agree bit-for-bit."""
    m, k, n, bs = 32, 32, 32, 16
    a, b = _exact_operands(RNG, m, k, n, bs, emax=3)
    aj = jnp.asarray(a, jnp.float32)
    bj = jnp.asarray(b, jnp.float32)
    cfg = BlockScaleConfig(block_m=bs, block_n=bs, block_k=bs)
    sa = np.asarray(compute_block_scales(aj, bs, bs, q_dtype))
    sb = np.asarray(compute_block_scales(bj, bs, bs, q_dtype))
    want = _oracle_blockscale(a, b, sa, sb, fmt, bs, bs, bs, "fp16alt")
    got = ops.blockscale_gemm(aj, bj, q_dtype_a=q_dtype, cfg=cfg,
                              out_dtype=jnp.bfloat16,
                              impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(got, np.float64), want)


@pytest.mark.parametrize("shape", [(64, 48, 32), (50, 48, 24), (100, 70, 30)],
                         ids=str)
def test_blockscale_pallas_matches_ref(shape):
    """Interpret-mode kernel vs pure-jnp ref on arbitrary float data
    (padding path included via non-multiple shapes)."""
    m, k, n = shape
    a = jnp.asarray(RNG.normal(0, 4, (m, k)), jnp.float32)
    b = jnp.asarray(RNG.normal(0, 4, (k, n)), jnp.float32)
    cfg = BlockScaleConfig(block_m=16, block_n=16, block_k=16)
    o_p = ops.blockscale_gemm(a, b, q_dtype_a=jnp.float8_e4m3, cfg=cfg,
                              impl="pallas_interpret")
    o_r = ops.blockscale_gemm(a, b, q_dtype_a=jnp.float8_e4m3, cfg=cfg,
                              impl="xla")
    tol = max(k * 2.0 ** -24, 1e-6)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               rtol=tol, atol=tol * np.sqrt(k))


# the regression test measures the exact workload the benchmark reports
from benchmarks.blockscale_gemm import outlier_matrix as _outlier_matrix


@pytest.mark.parametrize("q_dtype,emax",
                         [(jnp.float8_e4m3, 24), (jnp.float8_e5m2, 36)],
                         ids=["fp8alt", "fp8"])
def test_per_block_beats_per_tensor_mse(q_dtype, emax):
    """Regression (DESIGN.md §3): on an outlier-heavy matrix, per-block
    GEMM error is at least 10x below per-tensor (row-normalized MSE)."""
    m, k, n, bs = 128, 128, 64, 32
    rng = np.random.default_rng(5)
    a = jnp.asarray(_outlier_matrix(rng, m, k, bs, emax), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
    exact = np.asarray(a, np.float64) @ np.asarray(b, np.float64)

    def row_nmse(out):
        err = np.asarray(out, np.float64) - exact
        return float(np.mean((err ** 2).sum(1) / (exact ** 2).sum(1)))

    cfg = BlockScaleConfig(block_m=bs, block_n=bs, block_k=bs)
    blk = ops.blockscale_gemm(a, b, q_dtype_a=q_dtype, cfg=cfg, impl="xla")
    aq, sa = ops.quantize_tensor(a, q_dtype)
    bq, sb = ops.quantize_tensor(b, q_dtype)
    pt = ref.exsdotp_gemm_ref(aq, bq, sa * sb)
    assert row_nmse(blk) * 10 < row_nmse(pt), (row_nmse(blk), row_nmse(pt))


def test_compute_block_scales_properties():
    x = jnp.asarray(RNG.normal(0, 100, (64, 64)), jnp.float32)
    x = x.at[:16, :16].set(0.0)  # an all-zero tile
    s = compute_block_scales(x, 16, 16, jnp.float8_e4m3)
    s = np.asarray(s)
    assert s.shape == (4, 4)
    assert s[0, 0] == 1.0  # zero tile -> neutral scale
    assert (np.log2(s) == np.round(np.log2(s))).all()  # pow2
    # scaled amax fills (half, full] of the format's range
    max_normal = float(jnp.finfo(jnp.float8_e4m3).max)
    xb = np.abs(np.asarray(x)).reshape(4, 16, 4, 16).max((1, 3))
    filled = xb / s
    nz = xb > 0
    assert (filled[nz] <= max_normal).all()
    assert (filled[nz] > max_normal / 2).all()
    # non-pow2 mode: amax maps exactly onto max_normal
    s2 = np.asarray(compute_block_scales(x, 16, 16, jnp.float8_e4m3,
                                         pow2=False))
    np.testing.assert_allclose(xb[nz] / s2[nz], max_normal, rtol=1e-6)


def test_qlinear_block_policy_end_to_end():
    """hfp8_block trains: fwd+bwd finite, close to per-tensor hfp8 on
    well-scaled data, and much better on outlier-heavy activations."""
    from repro.core.linear import qlinear
    from repro.core.policy import get_policy
    rng = np.random.default_rng(3)
    pol_b = get_policy("hfp8_block")
    pol_t = get_policy("hfp8")
    x = jnp.asarray(rng.normal(0, 1, (4, 128, 128)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(0, 0.3, (128, 64)), jnp.bfloat16)

    def loss(pol):
        def f(x, w):
            return (qlinear(x, w, pol, impl="xla")
                    .astype(jnp.float32) ** 2).sum()
        return jax.jit(jax.value_and_grad(f, (0, 1)))

    vb, gb = loss(pol_b)(x, w)
    vt, gt = loss(pol_t)(x, w)
    assert np.isfinite(float(vb))
    assert all(bool(jnp.isfinite(g).all()) for g in gb)
    assert abs(float(vb) - float(vt)) / abs(float(vt)) < 0.05
    # outlier-heavy: one huge 128-token span (= one row tile of the
    # policy's 128x128 blocks) wrecks per-tensor, not per-block
    xo = np.asarray(x, np.float32)
    xo[0] *= 2.0 ** 24
    xo = jnp.asarray(xo, jnp.float32).astype(jnp.bfloat16)
    exact = (np.asarray(xo, np.float64).reshape(-1, 128)
             @ np.asarray(w, np.float64))
    yb = np.asarray(qlinear(xo, w, pol_b, impl="xla"),
                    np.float64).reshape(-1, 64)
    yt = np.asarray(qlinear(xo, w, pol_t, impl="xla"),
                    np.float64).reshape(-1, 64)
    pw = (exact ** 2).sum(1)
    nz = pw > 0
    eb = ((yb - exact) ** 2).sum(1)[nz] / pw[nz]
    et = ((yt - exact) ** 2).sum(1)[nz] / pw[nz]
    assert eb.mean() * 10 < et.mean(), (eb.mean(), et.mean())


# ---------------------------------------------------- vectorized oracle ---

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 32 - 1))
def test_vectorized_oracle_matches_bignum(seed):
    """The TwoSum/round-to-odd vector path == the exact dyadic path,
    element for element, across extreme scale mixtures (tie-heavy)."""
    rng = np.random.default_rng(seed)
    src, dst = [("fp8", "fp16"), ("fp8alt", "fp16alt"),
                ("fp16", "fp32")][seed % 3]
    n = 256
    scale = 4.0 ** rng.integers(-6, 7, n)
    # integer grids maximize exact ties at the dst rounding boundary
    a, c = (rng.integers(-8, 9, n) * scale for _ in range(2))
    b, d = (rng.integers(-8, 9, n).astype(np.float64) for _ in range(2))
    e = rng.integers(-8, 9, n) * scale * scale
    got = X.exsdotp_np(a, b, c, d, e, src, dst)
    fs, fd = F.get_format(src), F.get_format(dst)
    aq, bq, cq, dq = (F.quantize_np(x, fs) for x in (a, b, c, d))
    eq = F.quantize_np(e, fd)
    for i in range(n):
        want = X._exact_3sum_round(
            (aq[i] * bq[i], cq[i] * dq[i], eq[i]), fd)
        assert got[i] == want or (np.isnan(got[i]) and np.isnan(want)), (
            i, aq[i], bq[i], cq[i], dq[i], eq[i], got[i], want)


def test_vectorized_oracle_special_values():
    out = X.exsdotp_np([np.nan, np.inf, 1.0], 1.0, 1.0, 1.0,
                       [0.0, 0.0, np.inf], "fp16", "fp32")
    assert np.isnan(out[0])
    assert np.isposinf(out[1])
    assert np.isposinf(out[2])
    opp = X.exsdotp_np(np.inf, 1.0, -np.inf, 1.0, 0.0, "fp16", "fp32")
    assert np.isnan(opp[()])


def test_gemm_oracle_matches_plain_dot_when_exact():
    """Small-integer GEMM: the ExSdotp chain == the exact product."""
    rng = np.random.default_rng(9)
    a = rng.integers(-3, 4, (24, 17)).astype(np.float64)
    b = rng.integers(-3, 4, (17, 10)).astype(np.float64)
    got = X.exsdotp_gemm_np(a, b, "fp8alt", "fp32")  # odd K: trailing ExFMA
    np.testing.assert_array_equal(got, a @ b)
