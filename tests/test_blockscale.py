"""Block-scaled ExSdotp GEMM: fused Pallas kernel vs the vectorized
dyadic oracle, and the accuracy regression per-block vs per-tensor.

Bit-exactness strategy (mirrors test_kernels.py): data is constructed so
every intermediate — the in-kernel cast, the per-block pow2 rescale, the
fp32 accumulation — is exact; then the kernel, the jnp ref and the
``exsdotp_np``-chain oracle must agree bit for bit, in any summation
order.  Per-block dynamic range is made *extreme* (tiles spanning 2^±12)
— exactly the regime where per-tensor scaling collapses.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import fuzz
from repro.core import exsdotp as X
from repro.core import formats as F
from repro.core.scaling import BlockScaleConfig, compute_block_scales
from repro.kernels import ops, ref

RNG = np.random.default_rng(11)

FMTS = [("fp8", jnp.float8_e5m2), ("fp8alt", jnp.float8_e4m3)]


def _exact_operands(rng, m, k, n, bs, emax=12):
    """Integer-grid operands with per-(row/col)-block pow2 magnitudes.

    Each tile's amax is pinned to 7 so the pow2 scale is uniform along
    K; products and partial sums then stay exact in fp32 (see module
    docstring), while tiles span 2^-emax .. 2^emax.
    """
    na = rng.integers(-7, 8, (m, k)).astype(np.float64)
    nb = rng.integers(-7, 8, (k, n)).astype(np.float64)
    na[::bs, ::bs] = 7.0
    nb[::bs, ::bs] = 7.0
    ra = 2.0 ** rng.integers(-emax, emax + 1, (m // bs, 1))
    rc = 2.0 ** rng.integers(-emax, emax + 1, (1, n // bs))
    a = na * np.repeat(ra, bs, 0)
    b = nb * np.repeat(rc, bs, 1)
    return a, b


def _oracle_blockscale(a, b, sa, sb, src_fmt, bm, bn, bk, out_fmt):
    """Numpy oracle: per-block quantize → vectorized ExSdotp-chain GEMM →
    pow2 dequant → accumulate → one rounding into out_fmt."""
    m, k = a.shape
    _, n = b.shape
    out = np.zeros((m, n))
    for i in range(m // bm):
        for j in range(n // bn):
            acc = np.zeros((bm, bn))
            for t in range(k // bk):
                ab = a[i * bm:(i + 1) * bm, t * bk:(t + 1) * bk] / sa[i, t]
                bb = b[t * bk:(t + 1) * bk, j * bn:(j + 1) * bn] / sb[t, j]
                part = X.exsdotp_gemm_np(ab, bb, src_fmt, "fp32")
                acc = acc + part * (sa[i, t] * sb[t, j])
            out[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn] = acc
    return F.quantize_np(out, out_fmt)


@pytest.mark.parametrize("fmt,q_dtype", FMTS, ids=[f[0] for f in FMTS])
@pytest.mark.parametrize("out_fmt,out_dtype",
                         [("fp32", jnp.float32)], ids=["f32out"])
def test_fused_blockscale_bit_exact_vs_oracle(fmt, q_dtype, out_fmt,
                                              out_dtype):
    m, k, n, bs = 64, 48, 32, 16
    a, b = _exact_operands(RNG, m, k, n, bs)
    aj = jnp.asarray(a, jnp.float32)
    bj = jnp.asarray(b, jnp.float32)
    cfg = BlockScaleConfig(block_m=bs, block_n=bs, block_k=bs)
    sa = np.asarray(compute_block_scales(aj, bs, bs, q_dtype))
    sb = np.asarray(compute_block_scales(bj, bs, bs, q_dtype))
    assert (np.log2(sa) == np.round(np.log2(sa))).all()  # pow2 scales
    want = _oracle_blockscale(a, b, sa, sb, fmt, bs, bs, bs, out_fmt)
    got = ops.blockscale_gemm(aj, bj, q_dtype_a=q_dtype, cfg=cfg,
                              out_dtype=out_dtype, impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(got, np.float64), want)


@pytest.mark.parametrize("fmt,q_dtype", FMTS, ids=[f[0] for f in FMTS])
def test_fused_blockscale_bit_exact_narrow_out(fmt, q_dtype):
    """Milder dynamic range so bf16 output doesn't overflow: the final
    downcast (the unit's one rounding) must also agree bit-for-bit."""
    m, k, n, bs = 32, 32, 32, 16
    a, b = _exact_operands(RNG, m, k, n, bs, emax=3)
    aj = jnp.asarray(a, jnp.float32)
    bj = jnp.asarray(b, jnp.float32)
    cfg = BlockScaleConfig(block_m=bs, block_n=bs, block_k=bs)
    sa = np.asarray(compute_block_scales(aj, bs, bs, q_dtype))
    sb = np.asarray(compute_block_scales(bj, bs, bs, q_dtype))
    want = _oracle_blockscale(a, b, sa, sb, fmt, bs, bs, bs, "fp16alt")
    got = ops.blockscale_gemm(aj, bj, q_dtype_a=q_dtype, cfg=cfg,
                              out_dtype=jnp.bfloat16,
                              impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(got, np.float64), want)


@pytest.mark.parametrize("shape", [(64, 48, 32), (50, 48, 24), (100, 70, 30)],
                         ids=str)
def test_blockscale_pallas_matches_ref(shape):
    """Interpret-mode kernel vs pure-jnp ref on arbitrary float data
    (padding path included via non-multiple shapes)."""
    m, k, n = shape
    a = jnp.asarray(RNG.normal(0, 4, (m, k)), jnp.float32)
    b = jnp.asarray(RNG.normal(0, 4, (k, n)), jnp.float32)
    cfg = BlockScaleConfig(block_m=16, block_n=16, block_k=16)
    o_p = ops.blockscale_gemm(a, b, q_dtype_a=jnp.float8_e4m3, cfg=cfg,
                              impl="pallas_interpret")
    o_r = ops.blockscale_gemm(a, b, q_dtype_a=jnp.float8_e4m3, cfg=cfg,
                              impl="xla")
    tol = max(k * 2.0 ** -24, 1e-6)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               rtol=tol, atol=tol * np.sqrt(k))


# the regression test measures the exact workload the benchmark reports
from benchmarks.blockscale_gemm import outlier_matrix as _outlier_matrix


@pytest.mark.parametrize("q_dtype,emax",
                         [(jnp.float8_e4m3, 24), (jnp.float8_e5m2, 36)],
                         ids=["fp8alt", "fp8"])
def test_per_block_beats_per_tensor_mse(q_dtype, emax):
    """Regression (DESIGN.md §3): on an outlier-heavy matrix, per-block
    GEMM error is at least 10x below per-tensor (row-normalized MSE)."""
    m, k, n, bs = 128, 128, 64, 32
    rng = np.random.default_rng(5)
    a = jnp.asarray(_outlier_matrix(rng, m, k, bs, emax), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
    exact = np.asarray(a, np.float64) @ np.asarray(b, np.float64)

    def row_nmse(out):
        err = np.asarray(out, np.float64) - exact
        return float(np.mean((err ** 2).sum(1) / (exact ** 2).sum(1)))

    cfg = BlockScaleConfig(block_m=bs, block_n=bs, block_k=bs)
    blk = ops.blockscale_gemm(a, b, q_dtype_a=q_dtype, cfg=cfg, impl="xla")
    aq, sa = ops.quantize_tensor(a, q_dtype)
    bq, sb = ops.quantize_tensor(b, q_dtype)
    pt = ref.exsdotp_gemm_ref(aq, bq, sa * sb)
    assert row_nmse(blk) * 10 < row_nmse(pt), (row_nmse(blk), row_nmse(pt))


@pytest.mark.parametrize("q_dtype", [jnp.float8_e5m2, jnp.float8_e4m3],
                         ids=["fp8", "fp8alt"])
def test_blockscale_quantize_fuzz_impls_agree(q_dtype):
    """Shared fuzz harness (tests/fuzz.py): group-structured data with
    extreme per-strip magnitudes, a zero strip and non-finite elements —
    the interpret-mode quantize kernel and the jnp ref must agree."""
    x = jnp.asarray(fuzz.group_structured(
        np.random.default_rng(2), 64, 96, 32), jnp.float32)
    q, s = ops.quantize_blockwise(x, q_dtype, block_m=32, block_n=32,
                                  impl="pallas_interpret")
    qr, sr = ops.quantize_blockwise(x, q_dtype, block_m=32, block_n=32,
                                    impl="xla")
    np.testing.assert_array_equal(np.asarray(q, np.float32),
                                  np.asarray(qr, np.float32))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=3e-7)
    # non-finite tiles got the neutral scale (poison not laundered)
    assert np.isfinite(np.asarray(s)).all()


def test_compute_block_scales_properties():
    x = jnp.asarray(RNG.normal(0, 100, (64, 64)), jnp.float32)
    x = x.at[:16, :16].set(0.0)  # an all-zero tile
    s = compute_block_scales(x, 16, 16, jnp.float8_e4m3)
    s = np.asarray(s)
    assert s.shape == (4, 4)
    assert s[0, 0] == 1.0  # zero tile -> neutral scale
    assert (np.log2(s) == np.round(np.log2(s))).all()  # pow2
    # scaled amax fills (half, full] of the format's range
    max_normal = float(jnp.finfo(jnp.float8_e4m3).max)
    xb = np.abs(np.asarray(x)).reshape(4, 16, 4, 16).max((1, 3))
    filled = xb / s
    nz = xb > 0
    assert (filled[nz] <= max_normal).all()
    assert (filled[nz] > max_normal / 2).all()
    # non-pow2 mode: amax maps exactly onto max_normal
    s2 = np.asarray(compute_block_scales(x, 16, 16, jnp.float8_e4m3,
                                         pow2=False))
    np.testing.assert_allclose(xb[nz] / s2[nz], max_normal, rtol=1e-6)


def test_qlinear_block_policy_end_to_end():
    """hfp8_block trains: fwd+bwd finite, close to per-tensor hfp8 on
    well-scaled data, and much better on outlier-heavy activations."""
    from repro.core.linear import qlinear
    from repro.core.policy import get_policy
    rng = np.random.default_rng(3)
    pol_b = get_policy("hfp8_block")
    pol_t = get_policy("hfp8")
    x = jnp.asarray(rng.normal(0, 1, (4, 128, 128)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(0, 0.3, (128, 64)), jnp.bfloat16)

    def loss(pol):
        def f(x, w):
            return (qlinear(x, w, pol, impl="xla")
                    .astype(jnp.float32) ** 2).sum()
        return jax.jit(jax.value_and_grad(f, (0, 1)))

    vb, gb = loss(pol_b)(x, w)
    vt, gt = loss(pol_t)(x, w)
    assert np.isfinite(float(vb))
    assert all(bool(jnp.isfinite(g).all()) for g in gb)
    assert abs(float(vb) - float(vt)) / abs(float(vt)) < 0.05
    # outlier-heavy: one huge 128-token span (= one row tile of the
    # policy's 128x128 blocks) wrecks per-tensor, not per-block
    xo = np.asarray(x, np.float32)
    xo[0] *= 2.0 ** 24
    xo = jnp.asarray(xo, jnp.float32).astype(jnp.bfloat16)
    exact = (np.asarray(xo, np.float64).reshape(-1, 128)
             @ np.asarray(w, np.float64))
    yb = np.asarray(qlinear(xo, w, pol_b, impl="xla"),
                    np.float64).reshape(-1, 64)
    yt = np.asarray(qlinear(xo, w, pol_t, impl="xla"),
                    np.float64).reshape(-1, 64)
    pw = (exact ** 2).sum(1)
    nz = pw > 0
    eb = ((yb - exact) ** 2).sum(1)[nz] / pw[nz]
    et = ((yt - exact) ** 2).sum(1)[nz] / pw[nz]
    assert eb.mean() * 10 < et.mean(), (eb.mean(), et.mean())


# --------------------------------------------- narrow lane dims (bugfix) --

def test_blockscale_blocks_lane_legal():
    """Auto-shrunk tiles must stay compiled-TPU legal: lane axes (N of B
    and the output, K of A) are 128-multiples, M only sublane-aligned.
    Regression: narrow-N GEMMs (MoE router, small heads) used to get
    block_n=8 — accepted by xla/interpret, illegal on compiled Pallas."""
    cfg = BlockScaleConfig()
    for m, k, n in [(64, 48, 8), (8, 8, 8), (300, 200, 24), (128, 128, 128)]:
        bm, bn, bk = ops.blockscale_blocks(m, n, k, cfg)
        assert bn % 128 == 0, (m, k, n, bn)
        assert bk % 128 == 0, (m, k, n, bk)
        assert bm % 8 == 0, (m, k, n, bm)
    # explicit sub-128 configs are the caller's choice and unchanged
    small = BlockScaleConfig(block_m=16, block_n=16, block_k=16)
    assert ops.blockscale_blocks(64, 64, 64, small) == (16, 16, 16)


@pytest.mark.parametrize("fmt,q_dtype", FMTS, ids=[f[0] for f in FMTS])
@pytest.mark.parametrize("shape", [(16, 48, 8), (8, 16, 24)], ids=str)
def test_blockscale_narrow_bit_exact_vs_oracle(fmt, q_dtype, shape):
    """Narrow-N / narrow-K shapes against the ``exsdotp_gemm_np`` chain
    oracle, bit for bit, through the lane-legal auto-shrunk tiles."""
    m, k, n = shape
    rng = np.random.default_rng(7)
    a = rng.integers(-7, 8, (m, k)).astype(np.float64)
    b = rng.integers(-7, 8, (k, n)).astype(np.float64)
    a[0, 0] = 7.0  # pin amax so the pow2 scale divides exactly
    b[0, 0] = 7.0
    cfg = BlockScaleConfig()
    bm, bn, bk = ops.blockscale_blocks(m, n, k, cfg)
    ap = np.zeros((m + (-m) % bm, k + (-k) % bk)); ap[:m, :k] = a
    bp = np.zeros((k + (-k) % bk, n + (-n) % bn)); bp[:k, :n] = b
    sa = np.asarray(compute_block_scales(jnp.asarray(ap, jnp.float32),
                                         bm, bk, q_dtype))
    sb = np.asarray(compute_block_scales(jnp.asarray(bp, jnp.float32),
                                         bk, bn, q_dtype))
    want = _oracle_blockscale(ap, bp, sa, sb, fmt, bm, bn, bk,
                              "fp32")[:m, :n]
    for impl in ("pallas_interpret", "xla"):
        got = ops.blockscale_gemm(jnp.asarray(a, jnp.float32),
                                  jnp.asarray(b, jnp.float32),
                                  q_dtype_a=q_dtype, cfg=cfg, impl=impl)
        assert got.shape == (m, n)
        np.testing.assert_array_equal(np.asarray(got, np.float64), want)


# --------------------------------------------- native-rank (3D) operands --

def test_blockscale_gemm_native_rank_matches_flattened():
    """3D ``a`` keeps native rank with per-(batch, seq-tile) row tiles;
    when S is a tile multiple this is bit-identical to flattening, and
    the xla / interpret impls agree on the same scale granularity."""
    b, s, k, n = 3, 32, 48, 24
    rng = np.random.default_rng(13)
    a3 = jnp.asarray(rng.normal(0, 4, (b, s, k)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 4, (k, n)), jnp.float32)
    cfg = BlockScaleConfig(block_m=16, block_n=16, block_k=16)
    y3 = ops.blockscale_gemm(a3, w, q_dtype_a=jnp.float8_e4m3, cfg=cfg,
                             impl="xla")
    assert y3.shape == (b, s, n)
    y2 = ops.blockscale_gemm(a3.reshape(-1, k), w,
                             q_dtype_a=jnp.float8_e4m3, cfg=cfg, impl="xla")
    np.testing.assert_array_equal(np.asarray(y3).reshape(-1, n),
                                  np.asarray(y2))
    yp = ops.blockscale_gemm(a3, w, q_dtype_a=jnp.float8_e4m3, cfg=cfg,
                             impl="pallas_interpret")
    tol = max(k * 2.0 ** -24, 1e-6)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(y3),
                               rtol=tol, atol=tol * np.sqrt(k))
    # S NOT a tile multiple: per-batch padding keeps tiles inside each
    # batch row (never crossing batch boundaries), impls still agree
    a3o = jnp.asarray(rng.normal(0, 4, (b, 24, k)), jnp.float32)
    yo = ops.blockscale_gemm(a3o, w, q_dtype_a=jnp.float8_e4m3, cfg=cfg,
                             impl="xla")
    yop = ops.blockscale_gemm(a3o, w, q_dtype_a=jnp.float8_e4m3, cfg=cfg,
                              impl="pallas_interpret")
    assert yo.shape == (b, 24, n)
    np.testing.assert_allclose(np.asarray(yop), np.asarray(yo),
                               rtol=tol, atol=tol * np.sqrt(k))


def test_compute_block_scales_native_rank():
    """Leading dims are batch: the 3D grid equals the per-batch 2D grids
    stacked — tiles never cross batch boundaries."""
    x = jnp.asarray(RNG.normal(0, 10, (3, 32, 32)), jnp.float32)
    s3 = compute_block_scales(x, 16, 16, jnp.float8_e4m3)
    assert s3.shape == (3, 2, 2)
    for i in range(3):
        s2 = compute_block_scales(x[i], 16, 16, jnp.float8_e4m3)
        np.testing.assert_array_equal(np.asarray(s3[i]), np.asarray(s2))


# ------------------------------------------- non-finite handling (bugfix) --

def test_nonfinite_not_laundered_per_tensor():
    """An inf/NaN element must poison its own output, not silently zero
    the whole tensor via an inf scale."""
    x = jnp.asarray(RNG.normal(0, 1, (16, 16)), jnp.float32)
    for bad in (np.inf, np.nan):
        xb = x.at[3, 5].set(bad)
        q, s = ops.quantize_tensor(xb, jnp.float8_e5m2)
        assert np.isfinite(float(s))
        deq = np.asarray(q, np.float32) * float(s)
        assert not np.isfinite(deq[3, 5])
        # the rest of the tensor survives (not flushed to zero)
        mask = np.ones((16, 16), bool); mask[3, 5] = False
        assert np.abs(deq[mask]).max() > 0


def test_nonfinite_not_laundered_per_block():
    x = jnp.asarray(RNG.normal(0, 1, (32, 32)), jnp.float32)
    x = x.at[2, 3].set(jnp.inf).at[20, 20].set(jnp.nan)
    s = np.asarray(compute_block_scales(x, 16, 16, jnp.float8_e4m3))
    assert np.isfinite(s).all()  # poisoned tiles get neutral scale 1
    b = jnp.asarray(RNG.normal(0, 1, (32, 8)), jnp.float32)
    cfg = BlockScaleConfig(block_m=16, block_n=16, block_k=16)
    out = np.asarray(ops.blockscale_gemm(x, b, q_dtype_a=jnp.float8_e4m3,
                                         cfg=cfg, impl="xla"), np.float32)
    # the poisoned rows are non-finite; every other row survives (the
    # neutral scale means the poison stays confined to its own elements)
    assert not np.isfinite(out[2]).all()
    assert not np.isfinite(out[20]).all()
    clean = [r for r in range(32) if r not in (2, 20)]
    assert np.isfinite(out[clean]).all()


def test_nonfinite_reaches_loss_scale_skip():
    """End to end: a poisoned activation under hfp8_block produces
    non-finite grads, which check_and_update_scale refuses to apply."""
    from repro.core.linear import qlinear
    from repro.core.policy import get_policy
    from repro.core.scaling import check_and_update_scale, loss_scale_init
    pol = get_policy("hfp8_block")
    x = jnp.asarray(RNG.normal(0, 1, (2, 32, 32)), jnp.bfloat16)
    x = x.at[0, 0, 0].set(jnp.inf)
    w = jnp.asarray(RNG.normal(0, 0.3, (32, 16)), jnp.bfloat16)
    g = jax.grad(lambda x, w: (qlinear(x, w, pol, impl="xla")
                               .astype(jnp.float32) ** 2).sum(),
                 argnums=1)(x, w)
    assert not bool(jnp.isfinite(g).all())  # poison propagated, not zeroed
    state = loss_scale_init()
    _, new_state, skip = check_and_update_scale(state, {"w": g})
    assert bool(skip)
    assert float(new_state["scale"]) < float(state["scale"])


# ------------------------------------------ policy margin/pow2 (bugfix) --

def test_policy_block_margin_pow2_wired():
    """Policies can express quantization headroom: ``block_margin`` /
    ``block_pow2`` reach BlockScaleConfig instead of being dropped."""
    import dataclasses
    from repro.core.policy import get_policy
    base = get_policy("hfp8_block")
    assert base.block_cfg.margin == 1.0 and base.block_cfg.pow2 is True
    p = dataclasses.replace(base, block_margin=0.5, block_pow2=False)
    cfg = p.block_cfg
    assert cfg.margin == 0.5 and cfg.pow2 is False
    assert (cfg.block_m, cfg.block_n, cfg.block_k) == (128,) * 3
    # and the margin actually lands in the scales: amax/s == margin*max
    x = jnp.asarray(RNG.normal(0, 9, (32, 32)), jnp.float32)
    s = np.asarray(compute_block_scales(x, 16, 16, jnp.float8_e4m3,
                                        margin=0.5, pow2=False))
    amax = np.abs(np.asarray(x)).reshape(2, 16, 2, 16).max((1, 3))
    np.testing.assert_allclose(
        amax / s, 0.5 * float(jnp.finfo(jnp.float8_e4m3).max), rtol=1e-6)


# ---------------------------------------------------- vectorized oracle ---

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 32 - 1))
def test_vectorized_oracle_matches_bignum(seed):
    """The TwoSum/round-to-odd vector path == the exact dyadic path,
    element for element, across extreme scale mixtures (tie-heavy)."""
    rng = np.random.default_rng(seed)
    src, dst = [("fp8", "fp16"), ("fp8alt", "fp16alt"),
                ("fp16", "fp32")][seed % 3]
    n = 256
    scale = 4.0 ** rng.integers(-6, 7, n)
    # integer grids maximize exact ties at the dst rounding boundary
    a, c = (rng.integers(-8, 9, n) * scale for _ in range(2))
    b, d = (rng.integers(-8, 9, n).astype(np.float64) for _ in range(2))
    e = rng.integers(-8, 9, n) * scale * scale
    got = X.exsdotp_np(a, b, c, d, e, src, dst)
    fs, fd = F.get_format(src), F.get_format(dst)
    aq, bq, cq, dq = (F.quantize_np(x, fs) for x in (a, b, c, d))
    eq = F.quantize_np(e, fd)
    for i in range(n):
        want = X._exact_3sum_round(
            (aq[i] * bq[i], cq[i] * dq[i], eq[i]), fd)
        assert got[i] == want or (np.isnan(got[i]) and np.isnan(want)), (
            i, aq[i], bq[i], cq[i], dq[i], eq[i], got[i], want)


def test_vectorized_oracle_special_values():
    out = X.exsdotp_np([np.nan, np.inf, 1.0], 1.0, 1.0, 1.0,
                       [0.0, 0.0, np.inf], "fp16", "fp32")
    assert np.isnan(out[0])
    assert np.isposinf(out[1])
    assert np.isposinf(out[2])
    opp = X.exsdotp_np(np.inf, 1.0, -np.inf, 1.0, 0.0, "fp16", "fp32")
    assert np.isnan(opp[()])


def test_gemm_oracle_matches_plain_dot_when_exact():
    """Small-integer GEMM: the ExSdotp chain == the exact product."""
    rng = np.random.default_rng(9)
    a = rng.integers(-3, 4, (24, 17)).astype(np.float64)
    b = rng.integers(-3, 4, (17, 10)).astype(np.float64)
    got = X.exsdotp_gemm_np(a, b, "fp8alt", "fp32")  # odd K: trailing ExFMA
    np.testing.assert_array_equal(got, a @ b)
