"""Dynamic loss scaling: growth/skip/cap dynamics of the global scalar
scheme and the per-block (per-row-tile) variant (DESIGN.md §7/§8).

Previously only the skip path was exercised indirectly; here the full
state machine is stepped: growth exactly at the growth_interval
boundary, backoff with the 1.0 floor, the max_scale cap, and — for the
per-block state — independence of the tiles (one diverging block backs
off alone while its neighbours keep growing).
"""
import jax.numpy as jnp
import numpy as np

from repro.core.scaling import (block_loss_scale_init,
                                check_and_update_block_scales,
                                check_and_update_scale, loss_scale_init)


def _step(state, g, **kw):
    return check_and_update_scale(state, {"g": jnp.asarray(g, jnp.float32)},
                                  **kw)


def test_unscale_divides_by_current_scale():
    state = loss_scale_init(initial=2.0 ** 4)
    g = np.full((4,), 32.0, np.float32)
    unscaled, _, skip = _step(state, g)
    assert not bool(skip)
    np.testing.assert_array_equal(np.asarray(unscaled["g"]), g / 16.0)


def test_growth_exactly_at_interval_boundary():
    state = loss_scale_init(initial=4.0)
    g = np.ones((2,), np.float32)
    for i in range(5):
        _, state, skip = _step(state, g, growth_interval=3)
        if i < 2:       # steps 1..2: counting up, no growth yet
            assert float(state["scale"]) == 4.0
            assert int(state["good_steps"]) == i + 1
        elif i == 2:    # step 3 == growth_interval: double, reset counter
            assert float(state["scale"]) == 8.0
            assert int(state["good_steps"]) == 0
        assert not bool(skip)
    assert float(state["scale"]) == 8.0  # next window not complete yet


def test_backoff_halves_resets_and_floors_at_one():
    state = loss_scale_init(initial=4.0)
    _, state, _ = _step(state, np.ones(2, np.float32), growth_interval=3)
    assert int(state["good_steps"]) == 1
    bad = np.asarray([1.0, np.inf], np.float32)
    for want in (2.0, 1.0, 1.0, 1.0):  # halve, halve, then floor at 1.0
        _, state, skip = _step(state, bad)
        assert bool(skip)
        assert float(state["scale"]) == want
        assert int(state["good_steps"]) == 0  # counter reset on skip
    # NaN triggers the same path as inf
    _, state, skip = _step(state, np.asarray([np.nan], np.float32))
    assert bool(skip) and float(state["scale"]) == 1.0


def test_growth_caps_at_max_scale():
    state = loss_scale_init(initial=2.0 ** 23)
    g = np.ones((2,), np.float32)
    for _ in range(4):
        _, state, _ = _step(state, g, growth_interval=1,
                            max_scale=2.0 ** 24)
    assert float(state["scale"]) == 2.0 ** 24  # capped, not 2^27


# --------------------------------------------------------- per-block ------

def test_block_state_init():
    state = block_loss_scale_init(4, initial=2.0 ** 10)
    assert state["scale"].shape == (4,) and state["good_steps"].shape == (4,)
    np.testing.assert_array_equal(np.asarray(state["scale"]),
                                  np.full(4, 2.0 ** 10, np.float32))


def test_block_skip_confined_to_poisoned_tile():
    """One diverging row tile backs off alone; clean tiles keep growing
    through their own schedule — the whole point of per-block state."""
    state = block_loss_scale_init(4, initial=8.0)
    g = np.ones((8, 3), np.float32)      # 4 tiles × 2 rows
    g[5, 1] = np.inf                     # poison tile 2 only
    unscaled, state, skip = check_and_update_block_scales(
        state, jnp.asarray(g), growth_interval=1)
    np.testing.assert_array_equal(np.asarray(skip),
                                  [False, False, True, False])
    np.testing.assert_array_equal(np.asarray(state["scale"]),
                                  [16.0, 16.0, 4.0, 16.0])
    np.testing.assert_array_equal(np.asarray(state["good_steps"]),
                                  [0, 0, 0, 0])
    # unscaled divides each tile by ITS scale (the pre-update one)
    u = np.asarray(unscaled)
    np.testing.assert_array_equal(u[:2], g[:2] / 8.0)
    np.testing.assert_array_equal(u[6:], g[6:] / 8.0)
    assert np.isinf(u[5, 1])             # poison survives unscaling


def test_block_growth_boundary_floor_and_cap():
    state = block_loss_scale_init(2, initial=4.0)
    bad = np.ones((4, 2), np.float32)
    bad[0, 0] = np.nan                   # tile 0 permanently poisoned
    for _ in range(4):
        _, state, skip = check_and_update_block_scales(
            state, jnp.asarray(bad), growth_interval=2, max_scale=16.0)
        np.testing.assert_array_equal(np.asarray(skip), [True, False])
    # tile 0: 4 -> 2 -> 1 -> floor 1; tile 1: grew at steps 2 and 4
    np.testing.assert_array_equal(np.asarray(state["scale"]), [1.0, 16.0])
    for _ in range(4):
        _, state, _ = check_and_update_block_scales(
            state, jnp.asarray(bad), growth_interval=2, max_scale=16.0)
    assert float(state["scale"][1]) == 16.0  # capped


def test_block_skip_any_composes_with_global_logic():
    """skip.any() reproduces the scalar scheme's step-skip decision."""
    state = block_loss_scale_init(2)
    g = np.ones((4, 2), np.float32)
    _, _, skip = check_and_update_block_scales(state, jnp.asarray(g))
    assert not bool(skip.any())
    g[3, 0] = np.inf
    _, _, skip = check_and_update_block_scales(state, jnp.asarray(g))
    assert bool(skip.any())
    scalar_state = loss_scale_init()
    _, _, scalar_skip = check_and_update_scale(
        scalar_state, {"g": jnp.asarray(g)})
    assert bool(skip.any()) == bool(scalar_skip)
