"""Serving path (DESIGN.md §12): packed paged KV cache, block decode,
continuous batching.

Layers under test, bottom up:

1. ``serve.kv_cache`` — applicability routing (packed pages only for an
   MX cache format with a group-aligned head dim; carrier pages as the
   fallback), pool/page-table layout, footprint accounting (mxfp4 must
   hold >= 2.5x less HBM per sequence than bf16 pages);
2. the model contracts — ``init_cache(paged=...)`` and the generalized
   ``decode_step``: block prefill over the paged cache must reproduce
   per-token decode, and per-token decode must track teacher-forced
   prefill logits for every family (dense GQA, mamba2 hybrid, xlstm,
   enc-dec), carrier and packed modes;
3. ``serve.decode.generate`` — the temperature>0 key guard and block
   prefill;
4. ``serve.scheduler.ContinuousBatcher`` — greedy continuous batching
   must produce *identical* tokens to sequential ``generate``,
   including mid-flight admission into freed slots, and hand every
   page back to the allocator.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ModelConfig
from repro.core.policy import get_policy
from repro.models import build_model
from repro.serve import kv_cache as KV
from repro.serve.decode import generate
from repro.serve.scheduler import (ContinuousBatcher, PageAllocator,
                                   ServeRequest)


def _cfg(policy="mxfp8", head_dim=32, n_kv_heads=1):
    return ModelConfig(name=f"serve-{policy}-{head_dim}", family="dense",
                       n_layers=2, d_model=64, n_heads=2,
                       n_kv_heads=n_kv_heads, d_ff=128, vocab_size=97,
                       head_dim=head_dim, policy_name=policy,
                       attn_q_chunk=8)


@pytest.fixture(scope="module")
def dense_mx():
    cfg = _cfg("mxfp8")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.key(0))


# ----------------------------------------------------------- kv_cache ----

def test_paged_kv_applicable_routing():
    pol = get_policy("mxfp8")
    assert KV.paged_kv_applicable(_cfg("mxfp8"), pol)
    assert not KV.paged_kv_applicable(_cfg("mxfp8", head_dim=16), pol)
    assert not KV.paged_kv_applicable(_cfg("bf16"), get_policy("bf16"))
    assert not KV.paged_kv_applicable(_cfg("hfp8"), get_policy("hfp8"))


def test_init_paged_kv_layout():
    cfg = _cfg("mxfp4")
    kv, pt, lens = KV.init_paged_kv(cfg, get_policy("mxfp4"), batch=2,
                                    max_len=64, page_size=16)
    mp = KV.max_pages(64, 16)
    p_pool = 1 + 2 * mp
    hd = cfg.head_dim_eff
    assert sorted(kv) == ["kp", "ks", "vp", "vs"]
    assert kv["kp"].shape == (p_pool, 16, 1, hd // 2)   # fp4: 2 elems/byte
    assert kv["ks"].shape == (p_pool, 16, 1, hd // 32)
    assert kv["kp"].dtype == kv["ks"].dtype == jnp.uint8
    # identity table: sequence b owns pages 1 + b*mp .. contiguously;
    # page 0 is reserved trash
    assert pt.shape == (2, mp) and int(pt.min()) == 1
    np.testing.assert_array_equal(
        np.asarray(pt), 1 + np.arange(2 * mp).reshape(2, mp))
    np.testing.assert_array_equal(np.asarray(lens), 0)
    # carrier fallback: same paging, bf16 leaves
    kvc, _, _ = KV.init_paged_kv(cfg, get_policy("bf16"), batch=2,
                                 max_len=64, page_size=16)
    assert sorted(kvc) == ["k", "v"]
    assert kvc["k"].shape == (p_pool, 16, 1, hd)
    assert kvc["k"].dtype == jnp.bfloat16


def test_footprint_mxfp4_beats_bf16_by_2p5x():
    """The acceptance bar: >= 2.5x fewer cache bytes/seq for mxfp4 —
    and the analytic accounting must equal the real cache arrays."""
    cfg = _cfg("mxfp4")
    model = build_model(cfg)
    mp = KV.max_pages(64, 16)
    for pol in ("mxfp4", "bf16"):
        cache = model.init_cache(2, 64, paged=True) if pol == "mxfp4" \
            else build_model(_cfg("bf16")).init_cache(2, 64, paged=True)
        measured = sum(l.nbytes // l.shape[1] * mp
                       for l in jax.tree_util.tree_leaves(cache["kv"]))
        want = KV.paged_kv_bytes_per_seq(cfg if pol == "mxfp4"
                                         else _cfg("bf16"),
                                         get_policy(pol), 64)
        assert measured == want, pol
    b4 = KV.paged_kv_bytes_per_seq(cfg, get_policy("mxfp4"), 64)
    b16 = KV.paged_kv_bytes_per_seq(_cfg("bf16"), get_policy("bf16"), 64)
    assert b16 / b4 >= 2.5, (b16, b4)


def test_serve_cache_footprint_report():
    from repro.launch.hlo_analysis import (format_serve_cache_footprint,
                                           serve_cache_footprint)
    fp = serve_cache_footprint(_cfg("mxfp4"), "mxfp4", 64)
    assert fp["cache_format"] == "mxfp4e2m1"
    assert fp["compression_vs_bf16"] >= 2.5
    # misaligned head dim: honest carrier fallback in the report
    fp16 = serve_cache_footprint(_cfg("mxfp8", head_dim=16), "mxfp8", 64)
    assert fp16["cache_format"] == "carrier-bf16"
    assert fp16["compression_vs_bf16"] == 1.0
    assert "mxfp4e2m1" in format_serve_cache_footprint(
        _cfg("mxfp4"), "mxfp4", 64)


# ----------------------------------------------------- model contracts ---

def test_init_cache_modes(dense_mx):
    cfg, model, _ = dense_mx
    auto = model.init_cache(2, 32)            # mxfp8 + hd32 -> packed pages
    assert "pt" in auto and "kp" in auto["kv"]
    assert auto["kv"]["kp"].shape[0] == cfg.n_layers
    carrier = model.init_cache(2, 32, paged=False)
    assert "pt" not in carrier and "idx" in carrier["kv"]
    # misaligned head dim: auto stays carrier; forcing paged gives
    # carrier *pages* (the bf16 fallback), never packed
    model16 = build_model(_cfg("mxfp8", head_dim=16))
    assert "pt" not in model16.init_cache(2, 32)
    forced = model16.init_cache(2, 32, paged=True)
    assert "pt" in forced and "k" in forced["kv"]


@pytest.mark.parametrize("policy", ["mxfp8", "mxfp4"])
def test_block_prefill_matches_per_token_paged(policy):
    """One [B, S] decode_step == S single-token steps, on the packed
    paged cache: same pages, same quantization, same logits."""
    cfg = _cfg(policy)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jnp.asarray(np.random.default_rng(0).integers(1, 97, (2, 7)))
    step = jax.jit(functools.partial(model.decode_step, impl="xla"))
    c1 = model.init_cache(2, 32)
    lg_block, c1 = step(params, prompt, c1)
    c2 = model.init_cache(2, 32)
    lgs = []
    for i in range(7):
        lg, c2 = step(params, prompt[:, i], c2)
        lgs.append(lg)
    np.testing.assert_allclose(np.asarray(lg_block, np.float32),
                               np.asarray(jnp.stack(lgs, 1), np.float32),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c1["lens"]),
                                  np.asarray(c2["lens"]))
    for name in c1["kv"]:
        np.testing.assert_array_equal(np.asarray(c1["kv"][name]),
                                      np.asarray(c2["kv"][name]),
                                      err_msg=name)


def _decode_all(model, params, tokens, cache, aux=None):
    step = jax.jit(functools.partial(model.decode_step, impl="xla"))
    outs = []
    for i in range(tokens.shape[1]):
        lg, cache = step(params, tokens[:, i], cache)
        outs.append(lg)
    return jnp.stack(outs, 1)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "zamba2-7b", "xlstm-125m"])
def test_decode_matches_prefill_families(arch):
    """Per-token decode tracks teacher-forced prefill for the dense-GQA,
    mamba2-hybrid and xlstm families (carrier caches; reduced configs
    keep hd=16 so the paged pool is exercised separately)."""
    cfg = dataclasses.replace(ARCHS[arch].reduced(), policy_name="bf16")
    model = build_model(cfg)
    rng = np.random.default_rng(3)
    params = model.init(jax.random.key(3))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)))
    full, _ = jax.jit(functools.partial(model.apply, impl="xla"))(
        params, tokens)
    dec = _decode_all(model, params, tokens, model.init_cache(2, 8))
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_prefill_gqa_packed_cache():
    """Dense GQA (n_kv_heads < n_heads) through the *packed* paged
    cache: decode logits track prefill within cache-quantization
    tolerance, and exactly match a carrier-paged decode re-quantized...
    — here: packed-vs-prefill stays within the mxfp8 envelope."""
    cfg = _cfg("mxfp8", n_kv_heads=1)       # 2 heads share 1 KV head
    model = build_model(cfg)
    rng = np.random.default_rng(5)
    params = model.init(jax.random.key(5))
    tokens = jnp.asarray(rng.integers(1, 97, (2, 8)))
    full, _ = jax.jit(functools.partial(model.apply, impl="xla"))(
        params, tokens)
    cache = model.init_cache(2, 16)
    assert "kp" in cache["kv"]
    dec = _decode_all(model, params, tokens, cache)
    f = np.asarray(full, np.float32)
    d = np.asarray(dec, np.float32)
    # mxfp8-quantized KV shifts bf16 logits; gate on relative L2, not
    # elementwise rtol (near-zero logits have unbounded relative error)
    rel = np.linalg.norm(d - f) / np.linalg.norm(f)
    assert rel < 0.1, rel


def test_encdec_block_decode_matches_per_token():
    """Enc-dec keeps carrier caches, but grows block decode: a [B, S]
    step against the prefilled cross cache == S per-token steps."""
    cfg = dataclasses.replace(ARCHS["whisper-tiny"].reduced(),
                              policy_name="bf16")
    model = build_model(cfg)
    rng = np.random.default_rng(7)
    params = model.init(jax.random.key(7))
    frames = jnp.asarray(rng.normal(0, 1, (2, cfg.enc_seq, cfg.d_model)),
                         jnp.bfloat16)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 6)))
    step = jax.jit(functools.partial(model.decode_step, impl="xla"))
    c1 = model.prefill_cache(params, frames, model.init_cache(2, 16))
    lg_block, _ = step(params, tokens, c1)
    c2 = model.prefill_cache(params, frames, model.init_cache(2, 16))
    lg_tok = _decode_all(model, params, tokens, c2)
    np.testing.assert_allclose(np.asarray(lg_block, np.float32),
                               np.asarray(lg_tok, np.float32),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ generate ---

def test_generate_temperature_requires_key(dense_mx):
    cfg, model, params = dense_mx
    prompt = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(ValueError, match="temperature>0 requires key="):
        generate(model, params, prompt, max_new_tokens=2, max_len=16,
                 temperature=0.7)


def test_generate_temperature_with_key_samples(dense_mx):
    cfg, model, params = dense_mx
    prompt = jnp.asarray(np.random.default_rng(0).integers(1, 97, (2, 4)))
    out = generate(model, params, prompt, max_new_tokens=3, max_len=16,
                   temperature=0.7, key=jax.random.key(0), impl="xla")
    assert out.shape == (2, 3)
    assert (np.asarray(out) >= 0).all()
    assert (np.asarray(out) < cfg.vocab_size).all()


def test_generate_paged_vs_carrier_first_token(dense_mx):
    """The first greedy token depends only on the prompt prefill; the
    packed cache quantizes KV but the logit argmax must already agree
    on step one for a well-separated prompt — and the paged run must
    produce exactly max_new_tokens."""
    cfg, model, params = dense_mx
    prompt = jnp.asarray(np.random.default_rng(1).integers(1, 97, (2, 5)))
    out_p = generate(model, params, prompt, max_new_tokens=4, max_len=32,
                     impl="xla")
    out_c = generate(model, params, prompt, max_new_tokens=4, max_len=32,
                     impl="xla", paged=False)
    assert out_p.shape == out_c.shape == (2, 4)
    np.testing.assert_array_equal(np.asarray(out_p)[:, 0],
                                  np.asarray(out_c)[:, 0])


# ----------------------------------------------------------- scheduler ---

def test_page_allocator_roundtrip():
    a = PageAllocator(9)            # pages 1..8
    assert a.available == 8
    got = a.alloc(3)
    assert len(set(got)) == 3 and all(1 <= p <= 8 for p in got)
    with pytest.raises(RuntimeError):
        a.alloc(6)
    a.free(got)
    assert a.available == 8
    with pytest.raises(AssertionError):
        a.free([0])                 # trash page is not allocatable


def test_scheduler_temperature_requires_key(dense_mx):
    cfg, model, params = dense_mx
    with pytest.raises(ValueError, match="temperature>0 requires key="):
        ContinuousBatcher(model, params, max_batch=1, max_len=16,
                          temperature=0.5)


def test_scheduler_matches_sequential_generate(dense_mx):
    """The acceptance bar: greedy continuous batching == sequential
    generate, token for token — with max_batch < n_requests so retired
    slots are re-admitted mid-flight and their pages re-used."""
    cfg, model, params = dense_mx
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 97, n) for n in (5, 9, 3, 7)]
    want = {i: np.asarray(generate(model, params, jnp.asarray(p[None]),
                                   max_new_tokens=6, max_len=32,
                                   impl="xla"))[0]
            for i, p in enumerate(prompts)}
    cb = ContinuousBatcher(model, params, max_batch=2, max_len=32,
                           impl="xla")
    got = cb.run([ServeRequest(i, p, 6) for i, p in enumerate(prompts)])
    assert sorted(got) == [0, 1, 2, 3]
    for i in range(4):
        np.testing.assert_array_equal(got[i], want[i], err_msg=f"req {i}")
    # every page returned: freed slots really recycle their pages
    assert cb.alloc.available == 2 * cb.mp
    assert (cb.pt == 0).all() and (cb.lens == 0).all()


def test_scheduler_eos_stops_early(dense_mx):
    cfg, model, params = dense_mx
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, 97, 5)
    ref_out = np.asarray(generate(model, params, jnp.asarray(prompt[None]),
                                  max_new_tokens=8, max_len=32,
                                  impl="xla"))[0]
    eos = int(ref_out[2])           # a stop no later than the third token
    stop = int(np.nonzero(ref_out == eos)[0][0])   # first occurrence wins
    cb = ContinuousBatcher(model, params, max_batch=1, max_len=32,
                           impl="xla", eos_id=eos)
    got = cb.run([ServeRequest("r", prompt, 8)])["r"]
    np.testing.assert_array_equal(got, ref_out[:stop + 1])
    assert cb.alloc.available == cb.mp
