"""Deterministic fuzz harness: structured boundary/random generators
shared by test_formats.py, test_blockscale.py and test_mx.py.

No hypothesis dependency — every generator is a plain function of a
seeded ``numpy.random.Generator``, so a failure reproduces from the test
id alone.  The boundary sets are derived from the format's own
parameters: ulp neighbours (exact halfway points exercise RNE ties),
the subnormal plateau, the overflow threshold (max_normal + half an
ulp — the smallest value that rounds away from max_normal), and the
non-finite specials.
"""
import numpy as np


def boundary_values(fmt) -> np.ndarray:
    """The format-derived edge cases, positive and negative (f32)."""
    ulp1 = 2.0 ** -fmt.man_bits                      # ulp at 1.0
    top_ulp = 2.0 ** (fmt.max_exp - fmt.man_bits)    # ulp at max_normal
    vals = [
        0.0,
        # subnormal plateau: below min_subnormal/2 rounds to zero,
        # halfway points between subnormal steps are RNE ties
        fmt.min_subnormal, fmt.min_subnormal / 2, fmt.min_subnormal / 4,
        fmt.min_subnormal * 0.75, fmt.min_subnormal * 1.5,
        fmt.min_subnormal * 2.5,
        # normal/subnormal boundary
        fmt.min_normal, fmt.min_normal - fmt.min_subnormal / 2,
        fmt.min_normal + fmt.min_subnormal / 2,
        # ulp neighbours around 1.0 (tie at 1 + ulp/2)
        1.0, 1.0 + ulp1 / 2, 1.0 + ulp1, 1.0 + 1.5 * ulp1, 1.0 - ulp1 / 4,
        # overflow threshold: max_normal, the last tie below it, the
        # halfway point above it (first value that rounds away)
        fmt.max_normal, fmt.max_normal - top_ulp / 2,
        fmt.max_normal + top_ulp / 2, fmt.max_normal * 1.5,
        # non-finite
        np.inf,
    ]
    with np.errstate(over="ignore"):  # fp16alt/fp32 overflow f32 -> inf, fine
        out = np.asarray(vals, np.float32)
    out = np.concatenate([out, -out, np.asarray([np.nan], np.float32)])
    return out


def finite_values(rng, fmt, n: int) -> np.ndarray:
    """Random finite values spanning the format's whole range (f32):
    normals across every binade, subnormals, and near-overflow."""
    binades = rng.integers(fmt.min_exp - fmt.man_bits, fmt.max_exp + 1, n)
    mant = 1.0 + rng.random(n)
    sign = rng.choice([-1.0, 1.0], n)
    vals = sign * mant * np.exp2(binades.astype(np.float64))
    return vals.astype(np.float32)


def sample(rng, fmt, n: int = 256) -> np.ndarray:
    """Boundary values + random finite values, shuffled (f32)."""
    out = np.concatenate([boundary_values(fmt), finite_values(rng, fmt, n)])
    rng.shuffle(out)
    return out


def group_structured(rng, m: int, k: int, group: int, emax: int = 12,
                     *, specials: bool = True) -> np.ndarray:
    """Matrix with per-(row × group-along-K) pow2 magnitudes — the MX
    workload: unit Gaussians times 2^U[-emax, emax] per group, plus
    (optionally) one all-zero group, one inf and one NaN element.
    Magnitudes stay well inside f32 so scaled quotients never hit the
    f32 subnormal range (where XLA's FTZ and numpy disagree)."""
    assert k % group == 0
    mag = 2.0 ** rng.integers(-emax, emax + 1, (m, k // group))
    x = rng.normal(0, 1, (m, k)) * np.repeat(mag, group, axis=1)
    if specials and m >= 3 and k >= 3 * group:
        x[0, :group] = 0.0
        x[1, group + 1] = np.inf
        x[2, 2 * group + 2] = np.nan
    return x.astype(np.float32)


def all_bit_patterns(fmt) -> np.ndarray:
    """Every encoding of ``fmt`` as uint64 (2**width patterns)."""
    return np.arange(1 << fmt.width, dtype=np.uint64)


def fp6_lanes(rng, n: int = 4096) -> np.ndarray:
    """Deterministic sample of FP6 3-byte lanes as uint8 ``[L, 3]``.

    The structured part covers the lane-boundary cases exhaustively:
    every 4-tuple over the boundary code set (all-zero / all-one fields,
    the code that straddles each byte seam: 0x00, 0x01, 0x20, 0x2A,
    0x15, 0x3F) — 6^4 = 1296 lanes whose bits exercise every shift in
    the 4-in-3-bytes layout — plus ``n`` uniformly random lanes.  A
    nightly job sweeps all 2^24 lanes (tests/test_pack.py ``slow``);
    this sample keeps the tier-1 suite cheap without losing the seams.
    """
    import itertools
    boundary = np.asarray([0x00, 0x01, 0x20, 0x2A, 0x15, 0x3F], np.uint8)
    quads = np.asarray(list(itertools.product(boundary, repeat=4)),
                       np.uint8)
    rand = rng.integers(0, 64, (n, 4)).astype(np.uint8)
    codes = np.concatenate([quads, rand])
    c = codes.astype(np.uint32)
    v = c[:, 0] | (c[:, 1] << 6) | (c[:, 2] << 12) | (c[:, 3] << 18)
    return np.stack([v & 0xFF, (v >> 8) & 0xFF, (v >> 16) & 0xFF],
                    -1).astype(np.uint8)


def attention_shapes():
    """(bh, s, t, hd) sweep for the attention harness: ragged-ish S/T at
    block multiples (the kernel asserts divisibility rather than
    padding: 8-multiples pick up block 8, pow2 lengths the big tiles),
    S = T and S != T, and both head dims the packed formats care about
    (hd = 64 and 128 — whole groups of 32 either way)."""
    return [
        (2, 64, 64, 64),      # square, block 64
        (1, 128, 128, 128),   # square, the full 128 tile
        (2, 96, 96, 64),      # 96 = 3·32: falls to block 32
        (1, 64, 128, 64),     # S < T (packed KV longer than q)
        (1, 128, 64, 128),    # S > T
        (3, 40, 40, 64),      # 40 = 5·8: sublane-floor block 8
    ]


def exact_attention_operands(rng, bh, s, t, hd, *, causal=True,
                             specials=False):
    """Attention operands on which the online softmax is *exact* — the
    flash kernel is bitwise equal to a straight-softmax oracle in any
    block order.  Returns ``(q, k, v)`` f32.

    Construction: ``q[b, i]`` is one-hot at column ``i % hd`` with value
    8, so the logit for key ``j`` is just ``8·k[j, i%hd]·hd**-0.5`` —
    every k element is a logit carrier.  Carrier values are 0 (survivor)
    or -256 (suppressed): suppressed logits sit ≥ 128 below the row max
    of 0, so ``exp`` underflows to exactly 0.0 in f32 (cutoff ≈ -104)
    and every online rescale factor is exactly 0 (pre-survivor garbage
    is erased: 0·finite = 0) or exactly 1 (max unchanged).  Survivor
    count per carrier column is a power of two (1/2/4) — ``l`` is a
    pow2, so the final division is exact — and survivors for column
    ``c`` sit at key indices ≤ c, inside every causal row that uses the
    column.  v (and k: {0, -256}) draws from {0, ±64, ±128, ±256},
    which quantize *losslessly* under every MX element format (pow2
    group amax → exact E8M0 scale → pow2 quotients), and weighted sums
    of ≤ 4 such values are exact f32 integers.

    ``specials=True`` poisons one v group (NaN) on one key row: every
    unmasked query row goes NaN in exactly that group's columns, both
    in the kernel (payload·NaN-scale) and the oracle.  Use with
    ``causal=False`` only — a *partially*-masked causal tile still
    streams its masked columns, where kernel 0·NaN and the oracle's
    structural exclusion of masked keys legitimately differ.
    """
    vals = np.asarray([0.0, 64.0, -64.0, 128.0, -128.0, 256.0, -256.0])
    q = np.zeros((bh, s, hd), np.float32)
    rows = np.arange(s)
    q[:, rows, rows % hd] = 8.0
    k = np.full((bh, t, hd), -256.0)
    for b in range(bh):
        for c in range(hd):
            avail = (min(c, t - 1) if causal else t - 1) + 1
            count = int(rng.choice([n for n in (1, 2, 4) if n <= avail]))
            k[b, rng.choice(avail, size=count, replace=False), c] = 0.0
    v = rng.choice(vals, size=(bh, t, hd))
    if specials:
        v[:, t // 2, :32] = np.nan
    return (q, k.astype(np.float32), v.astype(np.float32))


def exact_decode_operands(rng, bh, s, t, hd, lens, *, specials=False,
                          garbage=True):
    """Decode-attention operands on which the base-offset online
    softmax is *exact* — the paged-cache kernel is bitwise equal to the
    straight-softmax oracle in any block order.  Returns
    ``(q, k, v, lens)`` with f32 operands and int32 lens.

    Same construction as ``exact_attention_operands`` shifted by the
    per-sequence history length: q row ``i`` of sequence ``b`` sits at
    absolute cache slot ``lens[b] + i`` and is one-hot at carrier
    column ``(lens[b] + i) % hd`` with value 8.  Survivor keys (carrier
    value 0 among -256 suppressors, pow2 count ≤ lens[b]+1) are placed
    at indices ``<= lens[b]`` — inside *every* query row's visible
    prefix, so no survivor is ever causally masked.

    ``garbage=True`` fills cache slots beyond each sequence's live
    prefix ``lens[b] + s`` with NaN — the stale-freed-page regime the
    kernels must exclude structurally (output must stay finite).

    ``specials=True`` additionally poisons one *fully visible* v group
    (NaN at slot ``min(lens)``, head columns 0..31): every query row of
    every sequence attends that slot (survivor → NaN·p, suppressed →
    NaN·0 = NaN in f32), so all outputs go NaN in exactly those
    columns, identically in kernel and oracle.
    """
    vals = np.asarray([0.0, 64.0, -64.0, 128.0, -128.0, 256.0, -256.0])
    lens = np.asarray(lens, np.int32)
    assert lens.shape == (bh,) and (lens + s <= t).all(), (lens, s, t)
    assert (lens >= 1).all(), lens   # slot min(lens) visible to every row
    q = np.zeros((bh, s, hd), np.float32)
    k = np.full((bh, t, hd), -256.0)
    for b in range(bh):
        cols = (int(lens[b]) + np.arange(s)) % hd
        q[b, np.arange(s), cols] = 8.0
        avail = int(lens[b]) + 1
        for c in np.unique(cols):
            count = int(rng.choice([n for n in (1, 2, 4) if n <= avail]))
            k[b, rng.choice(avail, size=count, replace=False), c] = 0.0
    v = rng.choice(vals, size=(bh, t, hd))
    if specials:
        v[:, int(lens.min()), :32] = np.nan
    if garbage:
        for b in range(bh):
            k[b, int(lens[b]) + s:] = np.nan
            v[b, int(lens[b]) + s:] = np.nan
    return q, k.astype(np.float32), v.astype(np.float32), lens


def exact_mx_operands(rng, m, k, n, mx, span=16, specials=True):
    """GEMM operands on which every fp32 intermediate is exact.

    A: per-(row × group) pow2 magnitudes 2^U[-span/2, span/2] (the first
    row is pinned to the full 2^span dynamic range) times small-int
    grids, with each group's amax pinned to the largest power of two at
    or below the element max (in (max/2, max], so the recovered E8M0
    scale is exactly the chosen pow2).  One group is poisoned with
    inf/NaN.  B: small ints, supported only on group ``j % G`` per
    column ``j`` — every output element then accumulates 32 products
    that share one scale class, so f32 sums are exact in any order.
    """
    import math
    g, G = mx.group, k // mx.group
    pin = 2.0 ** math.floor(math.log2(mx.elem.max_normal))
    ea = rng.integers(-span // 2, span // 2 + 1, (m, G)).astype(np.float64)
    ea[0, 0], ea[0, 1] = -span // 2, span // 2
    qa = rng.integers(-2, 3, (m, k)).astype(np.float64)
    qa[:, ::g] = pin * np.sign(rng.integers(0, 2, (m, G)) * 2 - 1)
    a = qa * np.repeat(2.0 ** ea, g, axis=1)
    if specials:
        a[1, g:2 * g] = np.inf
        a[1, g + 3] = np.nan
    b = np.zeros((k, n))
    for j in range(n):
        gj = j % G
        b[gj * g:(gj + 1) * g, j] = rng.integers(-2, 3, g)
    return a, b
