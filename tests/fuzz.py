"""Deterministic fuzz harness: structured boundary/random generators
shared by test_formats.py, test_blockscale.py and test_mx.py.

No hypothesis dependency — every generator is a plain function of a
seeded ``numpy.random.Generator``, so a failure reproduces from the test
id alone.  The boundary sets are derived from the format's own
parameters: ulp neighbours (exact halfway points exercise RNE ties),
the subnormal plateau, the overflow threshold (max_normal + half an
ulp — the smallest value that rounds away from max_normal), and the
non-finite specials.
"""
import numpy as np


def boundary_values(fmt) -> np.ndarray:
    """The format-derived edge cases, positive and negative (f32)."""
    ulp1 = 2.0 ** -fmt.man_bits                      # ulp at 1.0
    top_ulp = 2.0 ** (fmt.max_exp - fmt.man_bits)    # ulp at max_normal
    vals = [
        0.0,
        # subnormal plateau: below min_subnormal/2 rounds to zero,
        # halfway points between subnormal steps are RNE ties
        fmt.min_subnormal, fmt.min_subnormal / 2, fmt.min_subnormal / 4,
        fmt.min_subnormal * 0.75, fmt.min_subnormal * 1.5,
        fmt.min_subnormal * 2.5,
        # normal/subnormal boundary
        fmt.min_normal, fmt.min_normal - fmt.min_subnormal / 2,
        fmt.min_normal + fmt.min_subnormal / 2,
        # ulp neighbours around 1.0 (tie at 1 + ulp/2)
        1.0, 1.0 + ulp1 / 2, 1.0 + ulp1, 1.0 + 1.5 * ulp1, 1.0 - ulp1 / 4,
        # overflow threshold: max_normal, the last tie below it, the
        # halfway point above it (first value that rounds away)
        fmt.max_normal, fmt.max_normal - top_ulp / 2,
        fmt.max_normal + top_ulp / 2, fmt.max_normal * 1.5,
        # non-finite
        np.inf,
    ]
    with np.errstate(over="ignore"):  # fp16alt/fp32 overflow f32 -> inf, fine
        out = np.asarray(vals, np.float32)
    out = np.concatenate([out, -out, np.asarray([np.nan], np.float32)])
    return out


def finite_values(rng, fmt, n: int) -> np.ndarray:
    """Random finite values spanning the format's whole range (f32):
    normals across every binade, subnormals, and near-overflow."""
    binades = rng.integers(fmt.min_exp - fmt.man_bits, fmt.max_exp + 1, n)
    mant = 1.0 + rng.random(n)
    sign = rng.choice([-1.0, 1.0], n)
    vals = sign * mant * np.exp2(binades.astype(np.float64))
    return vals.astype(np.float32)


def sample(rng, fmt, n: int = 256) -> np.ndarray:
    """Boundary values + random finite values, shuffled (f32)."""
    out = np.concatenate([boundary_values(fmt), finite_values(rng, fmt, n)])
    rng.shuffle(out)
    return out


def group_structured(rng, m: int, k: int, group: int, emax: int = 12,
                     *, specials: bool = True) -> np.ndarray:
    """Matrix with per-(row × group-along-K) pow2 magnitudes — the MX
    workload: unit Gaussians times 2^U[-emax, emax] per group, plus
    (optionally) one all-zero group, one inf and one NaN element.
    Magnitudes stay well inside f32 so scaled quotients never hit the
    f32 subnormal range (where XLA's FTZ and numpy disagree)."""
    assert k % group == 0
    mag = 2.0 ** rng.integers(-emax, emax + 1, (m, k // group))
    x = rng.normal(0, 1, (m, k)) * np.repeat(mag, group, axis=1)
    if specials and m >= 3 and k >= 3 * group:
        x[0, :group] = 0.0
        x[1, group + 1] = np.inf
        x[2, 2 * group + 2] = np.nan
    return x.astype(np.float32)


def all_bit_patterns(fmt) -> np.ndarray:
    """Every encoding of ``fmt`` as uint64 (2**width patterns)."""
    return np.arange(1 << fmt.width, dtype=np.uint64)
