"""Bit-exactness tests for the MiniFloat-NN format layer (paper §III-A)."""
import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

import fuzz
from repro.core import formats as F

RNG = np.random.default_rng(0)

CASES = [
    (F.FP8, ml_dtypes.float8_e5m2),
    (F.FP8ALT, ml_dtypes.float8_e4m3),
    (F.FP16, np.float16),
    (F.FP16ALT, ml_dtypes.bfloat16),
]


def _interesting_values(fmt):
    """Sweep: normals, subnormals, halfway points, overflow, specials."""
    vals = [0.0, -0.0, fmt.min_subnormal, fmt.min_subnormal / 2,
            fmt.min_subnormal * 1.5, fmt.min_normal, fmt.max_normal,
            fmt.max_normal * (1 + 2.0 ** (-fmt.man_bits - 1)),  # exactly half ulp over
            fmt.max_normal * 1.5, np.inf, -np.inf]
    vals += list(RNG.normal(0, 2.0, 512))
    vals += list(RNG.normal(0, 2.0, 256) * fmt.max_normal)
    vals += list(RNG.normal(0, 4.0, 256) * fmt.min_normal)
    out = np.array(vals, np.float32)
    return np.concatenate([out, -out])


@pytest.mark.parametrize("fmt,mld", CASES, ids=[c[0].name for c in CASES])
def test_quantize_matches_native_cast(fmt, mld):
    x = _interesting_values(fmt)
    ours = np.asarray(F.quantize(jnp.asarray(x), fmt))
    ref = x.astype(mld).astype(np.float32)
    np.testing.assert_array_equal(ours, ref)


@pytest.mark.parametrize("fmt,mld", CASES, ids=[c[0].name for c in CASES])
def test_quantize_np_matches_native_cast(fmt, mld):
    x = _interesting_values(fmt)
    ours = F.quantize_np(x, fmt).astype(np.float32)
    ref = x.astype(mld).astype(np.float32)
    np.testing.assert_array_equal(ours, ref)


@pytest.mark.parametrize("fmt,mld", CASES, ids=[c[0].name for c in CASES])
def test_encode_decode_roundtrip(fmt, mld):
    x = _interesting_values(fmt)
    q = F.quantize_np(x, fmt)
    bits = F.encode_np(x, fmt)
    back = F.decode_np(bits, fmt)
    finite = np.isfinite(q)
    np.testing.assert_array_equal(back[finite], q[finite])
    np.testing.assert_array_equal(np.isinf(back), np.isinf(q))
    np.testing.assert_array_equal(np.isnan(back), np.isnan(q))


def test_nan_propagation():
    for fmt in (F.FP8, F.FP8ALT, F.FP16, F.FP16ALT):
        out = np.asarray(F.quantize(jnp.asarray([np.nan, 1.0]), fmt))
        assert np.isnan(out[0]) and not np.isnan(out[1])


def test_quantize_idempotent():
    for fmt in (F.FP8, F.FP8ALT, F.FP16, F.FP16ALT):
        x = RNG.normal(0, 10, 4096).astype(np.float32)
        q1 = np.asarray(F.quantize(jnp.asarray(x), fmt))
        q2 = np.asarray(F.quantize(jnp.asarray(q1), fmt))
        np.testing.assert_array_equal(q1, q2)


def test_format_constants_match_paper():
    # paper Fig. 1 widths
    assert (F.FP8.exp_bits, F.FP8.man_bits) == (5, 2)
    assert (F.FP8ALT.exp_bits, F.FP8ALT.man_bits) == (4, 3)
    assert (F.FP16.exp_bits, F.FP16.man_bits) == (5, 10)
    assert (F.FP16ALT.exp_bits, F.FP16ALT.man_bits) == (8, 7)
    # FP8 shares FP16's dynamic range (paper §II-A)
    assert F.FP8.max_exp == F.FP16.max_exp == 15
    # expanding pairs (Table I)
    assert F.EXPANDING_DST["fp8"] is F.FP16
    assert F.EXPANDING_DST["fp16"] is F.FP32


def test_saturating_variant():
    fmt = F.MiniFloatFormat("fp8sat", 5, 2, inf_behavior="saturate")
    out = np.asarray(F.quantize(jnp.asarray([1e9, -1e9]), fmt))
    np.testing.assert_array_equal(out, [fmt.max_normal, -fmt.max_normal])


# ------------------------------------------------- exhaustive round-trips --

@pytest.mark.parametrize("fmt,mld", [(F.FP8, ml_dtypes.float8_e5m2),
                                     (F.FP8ALT, ml_dtypes.float8_e4m3)],
                         ids=["fp8", "fp8alt"])
def test_exhaustive_8bit_roundtrip(fmt, mld):
    """All 256 bit patterns: decode -> quantize (idempotent) -> encode is
    the identity for every non-NaN pattern (subnormals, ±0, ±inf
    included); NaN patterns decode to NaN and re-encode to a NaN
    pattern.  Decoded values are cross-checked against the native
    ml_dtypes view of the same bits."""
    bits = fuzz.all_bit_patterns(fmt)
    vals = F.decode_np(bits, fmt)
    native = bits.astype(np.uint8).view(mld).astype(np.float32)
    np.testing.assert_array_equal(vals.astype(np.float32), native)
    np.testing.assert_array_equal(np.signbit(vals), np.signbit(native))
    # decoded values are fixed points of the quantizers
    q = F.quantize_np(vals, fmt)
    qj = np.asarray(F.quantize(jnp.asarray(vals, jnp.float32), fmt))
    nan = np.isnan(vals)
    np.testing.assert_array_equal(q[~nan], vals[~nan])
    np.testing.assert_array_equal(qj[~nan], vals[~nan].astype(np.float32))
    assert np.isnan(q[nan]).all() and np.isnan(qj[nan]).all()
    # encode round-trips the exact bit pattern (quiet-NaN canonicalized)
    back = F.encode_np(vals, fmt)
    np.testing.assert_array_equal(back[~nan], bits[~nan])
    exp_mask = ((1 << fmt.exp_bits) - 1) << fmt.man_bits
    man_mask = (1 << fmt.man_bits) - 1
    renan = back[nan]
    assert ((renan & exp_mask) == exp_mask).all()
    assert ((renan & man_mask) != 0).all()


@pytest.mark.parametrize("fmt", [F.FP6E2M3, F.FP6E3M2, F.FP4E2M1],
                         ids=lambda f: f.name)
def test_exhaustive_subbyte_roundtrip(fmt):
    """Sub-byte OCP element formats have no special codes, so decode ->
    quantize -> encode is the identity for *every* pattern; decoded
    values match the native ml_dtypes "fn" dtype's view bit for bit."""
    bits = fuzz.all_bit_patterns(fmt)
    vals = F.decode_np(bits, fmt)
    assert np.isfinite(vals).all()
    np.testing.assert_array_equal(F.quantize_np(vals, fmt), vals)
    np.testing.assert_array_equal(
        np.asarray(F.quantize(jnp.asarray(vals, jnp.float32), fmt)),
        vals.astype(np.float32))
    np.testing.assert_array_equal(F.encode_np(vals, fmt), bits)
    if fmt.ml_dtype is not None:
        native = bits.astype(np.uint8).view(fmt.ml_dtype).astype(np.float32)
        np.testing.assert_array_equal(vals.astype(np.float32), native)
        np.testing.assert_array_equal(np.signbit(vals), np.signbit(native))


@pytest.mark.parametrize("fmt,mld", CASES + [
    (F.FP6E2M3, F.FP6E2M3.ml_dtype), (F.FP6E3M2, F.FP6E3M2.ml_dtype),
    (F.FP4E2M1, F.FP4E2M1.ml_dtype)],
    ids=[c[0].name for c in CASES] + ["fp6e2m3", "fp6e3m2", "fp4e2m1"])
def test_fuzz_boundaries_match_native(fmt, mld):
    """Structured fuzz sweep (tests/fuzz.py): ulp neighbours, subnormal
    plateau, overflow threshold and non-finite values all quantize
    identically to the native cast, in both implementations."""
    if mld is None:
        pytest.skip("no native dtype in this ml_dtypes")
    x = fuzz.sample(np.random.default_rng(0), fmt, n=512)
    if not fmt.ieee_specials:
        # "fn" dtypes disagree on non-finite inputs (they have no NaN to
        # return); the emulation keeps NaN in value space, the MX layer
        # handles non-finites via the E8M0 NaN scale.
        x = x[np.isfinite(x)]
    ref = x.astype(mld).astype(np.float32)
    np.testing.assert_array_equal(F.quantize_np(x, fmt).astype(np.float32),
                                  ref)
    np.testing.assert_array_equal(
        np.asarray(F.quantize(jnp.asarray(x), fmt)), ref)
