"""Substrate tests: optimizer, loss scaling, data, checkpointing,
fault-tolerant resume, gradient compression, serving."""
import os

from repro.compat import make_mesh
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.scaling import loss_scale_init, check_and_update_scale
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.train.train_step import make_train_state, make_train_step
from repro.train.trainer import Trainer
from repro.checkpoint.ckpt import CheckpointManager


# ----------------------------------------------------------- optimizer ----

def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      schedule="constant")
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params, cfg)
    for _ in range(200):
        g = {"w": 2 * params["w"]}  # d/dw (w^2)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_skip_freezes_state():
    cfg = AdamWConfig()
    params = {"w": jnp.ones(4)}
    opt = adamw_init(params, cfg)
    g = {"w": jnp.ones(4)}
    p2, opt2, _ = adamw_update(g, opt, params, cfg,
                               skip=jnp.array(True))
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.ones(4))
    assert int(opt2["step"]) == 0


def test_adamw_low_precision_state():
    cfg = AdamWConfig(master_dtype=jnp.float16, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones(8, jnp.bfloat16)}
    opt = adamw_init(params, cfg)
    assert opt["master"]["w"].dtype == jnp.float16
    assert opt["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full(8, 0.5, jnp.float32)}
    p2, opt2, m = adamw_update(g, opt, params, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert np.isfinite(float(m["grad_norm"]))


# --------------------------------------------------------- loss scaling ---

def test_loss_scale_shrinks_on_overflow_and_grows_back():
    st = loss_scale_init(2.0 ** 10)
    bad = {"g": jnp.array([jnp.inf])}
    _, st2, skip = check_and_update_scale(st, bad)
    assert bool(skip) and float(st2["scale"]) == 2.0 ** 9
    good = {"g": jnp.array([1.0])}
    st3 = st2
    for _ in range(3):
        _, st3, skip = check_and_update_scale(st3, good, growth_interval=2)
    assert float(st3["scale"]) > 2.0 ** 9


# ----------------------------------------------------------------- data ---

def test_data_deterministic_and_host_sharded():
    d = SyntheticTokens(DataConfig(vocab_size=1000, seq_len=16,
                                   global_batch=8))
    b1 = d.global_batch_at_step(3)
    b2 = d.global_batch_at_step(3)
    np.testing.assert_array_equal(b1, b2)
    assert (b1 != d.global_batch_at_step(4)).any()
    h0 = d.host_batch_at_step(3, 0, 2)
    h1 = d.host_batch_at_step(3, 1, 2)
    np.testing.assert_array_equal(np.concatenate([h0, h1]), b1)
    assert b1.min() >= 0 and b1.max() < 1000


# ----------------------------------------------------------- checkpoint ---

def test_checkpoint_roundtrip_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.float32(3.5)}}
    for s in (5, 10, 15):
        mgr.save(s, tree)
    assert mgr.latest_step() == 15
    like = jax.tree.map(jnp.zeros_like, tree)
    back = mgr.restore(15, like)
    np.testing.assert_array_equal(np.asarray(back["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    # keep=2 garbage-collects the oldest
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2


# ------------------------------------------------- end-to-end training ----

def _tiny_setup(tmp_path, fail_at=None):
    cfg = ARCHS["qwen2.5-3b"].reduced()
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, schedule="constant")
    state = make_train_state(model, jax.random.key(0), opt_cfg)
    step = make_train_step(model, opt_cfg, impl="xla")
    data = SyntheticTokens(DataConfig(cfg.vocab_size, seq_len=16,
                                      global_batch=4))
    tr = Trainer(model, step, state, data, ckpt_dir=str(tmp_path),
                 save_every=2, fail_at_step=fail_at)
    return tr


def test_training_runs_and_loss_finite(tmp_path):
    tr = _tiny_setup(tmp_path / "a")
    log = tr.run(4)
    assert len(log) == 4
    assert all(np.isfinite(m["loss"]) for m in log)
    assert log[-1]["skipped"] == 0


def test_failure_resume_is_bit_exact(tmp_path):
    # uninterrupted reference run: 6 steps
    ref = _tiny_setup(tmp_path / "ref")
    ref.run(6)
    ref_leaves = jax.tree.leaves(ref.state["params"])

    # interrupted run: dies at step 4 (checkpoints published at 2 and 4)
    tr = _tiny_setup(tmp_path / "crash", fail_at=4)
    with pytest.raises(RuntimeError):
        tr.run(6)
    # "new process": fresh trainer auto-resumes from the last *published*
    # checkpoint (the crash-time flush makes that step 4)
    tr2 = _tiny_setup(tmp_path / "crash")
    assert tr2.start_step in (2, 4)
    tr2.run(6 - tr2.start_step)  # finish the remaining steps
    for a, b in zip(ref_leaves, jax.tree.leaves(tr2.state["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_straggler_detection(tmp_path):
    tr = _tiny_setup(tmp_path / "s")
    seen = []
    tr.on_straggler = lambda step, dt: seen.append(step)
    import time as _t
    orig = tr.train_step

    def slow_step(state, batch):
        out = orig(state, batch)
        if len(tr.step_times) == 5:
            _t.sleep(1.0)
        return out

    tr.train_step = slow_step
    tr.run(7)
    assert tr.straggler_count >= 1


# ----------------------------------------------------- grad compression ---

def test_compressed_psum_matches_mean_with_error_feedback():
    # needs >1 device: simulate with a 1-device mesh reduction identity,
    # plus the pure quantization error-feedback property single-device.
    from repro.optim.grad_compress import (compressed_psum_mean,
                                           error_feedback_init)
    mesh = make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, (64,)),
                          jnp.float32)}
    ef = error_feedback_init(g)
    acc_true = np.zeros(64)
    acc_comp = np.zeros(64)
    for _ in range(50):
        red, ef = compressed_psum_mean(g, ef, mesh, "data")
        acc_true += np.asarray(g["w"])
        acc_comp += np.asarray(red["w"])
    # error feedback keeps the *accumulated* estimate tight even though a
    # single fp8 reduction is coarse
    rel = np.abs(acc_comp - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.01


# -------------------------------------------------------------- serving ---

def test_generate_greedy():
    from repro.serve.decode import generate
    cfg = ARCHS["deepseek-7b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 4)))
    toks = generate(model, params, prompt, max_new_tokens=3, max_len=16)
    assert toks.shape == (2, 3)
    assert int(toks.max()) < cfg.vocab_size
