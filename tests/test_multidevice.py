"""Multi-device integration tests (subprocess with forced device count):

* compressed fp8 gradient all-reduce == exact mean (within fp8 error),
  error feedback keeps accumulated drift tiny;
* a (data=2, model=2)-sharded train step produces the same losses as the
  single-device step — the sharding rules don't change the math.
"""
import os
import subprocess
import sys
import textwrap


def _run(script: str, timeout=560):
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=timeout,
                       env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, (r.stderr[-3000:] or r.stdout[-3000:])
    return r.stdout


def test_compressed_allreduce_8dev():
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.optim.grad_compress import (compressed_psum_mean,
                                               error_feedback_init)
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        # per-device distinct gradients, laid out on the data axis
        g_all = rng.normal(0, 1, (8, 256)).astype(np.float32)
        gd = jax.device_put(jnp.asarray(g_all),
                            NamedSharding(mesh, P("data", None)))

        # reduce over data: wrap so each shard passes its own row
        import functools
        def one(g, e):
            r, ne = compressed_psum_mean({"w": g}, {"w": e}, mesh, "data")
            return r["w"], ne["w"]
        ef = jnp.zeros((8, 256), jnp.float32)
        efd = jax.device_put(ef, NamedSharding(mesh, P("data", None)))

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P("data", None), P("data", None)),
                           out_specs=(P("data", None), P("data", None)),
                           check_vma=False)
        def run(g, e):
            from repro.optim.grad_compress import _quantize_leaf
            gc = g[0] + e[0]
            q, s = _quantize_leaf(gc, jnp.float8_e5m2)
            ne = gc - q.astype(jnp.float32) * s
            qs = jax.lax.all_gather(q, "data")
            ss = jax.lax.all_gather(s, "data")
            red = jnp.tensordot(ss, qs.astype(jnp.float32), axes=((0,),(0,)))
            return (red / 8)[None], ne[None]

        acc_t = np.zeros(256); acc_c = np.zeros(256)
        e = efd
        for it in range(30):
            red, e = run(gd, e)
            acc_t += g_all.mean(0)
            acc_c += np.asarray(red)[0]
        rel = np.abs(acc_c - acc_t).max() / (np.abs(acc_t).max() + 1e-9)
        assert rel < 0.02, rel
        # single-shot fp8 reduction is coarse (>= 1% typ); EF fixed it
        print("COMP_OK", rel)
    """))
    assert "COMP_OK" in out


def test_sharded_train_step_matches_single_device():
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh, set_mesh
        from repro.configs import ARCHS
        from repro.models import build_model
        from repro.optim.adamw import AdamWConfig
        from repro.parallel.sharding import make_rules, param_pspecs
        from repro.train.train_step import make_train_state, make_train_step

        cfg = ARCHS["deepseek-7b"].reduced()
        model = build_model(cfg)
        opt = AdamWConfig(lr=1e-3, warmup_steps=2, schedule="constant")
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)))

        def losses(mesh):
            from contextlib import nullcontext
            state = make_train_state(model, jax.random.key(0), opt)
            rules = make_rules(mesh) if mesh else None
            step = make_train_step(model, opt, rules=rules, impl="xla")
            if mesh is not None:
                pspecs = param_pspecs(
                    jax.eval_shape(lambda: state["params"]), mesh)
                sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                    is_leaf=lambda x: type(x).__name__ == "PartitionSpec")
                state["params"] = jax.tree.map(jax.device_put,
                                               state["params"], sh)
            out = []
            stepj = jax.jit(step)
            with set_mesh(mesh) if mesh is not None else nullcontext():
                for _ in range(3):
                    state, m = stepj(state, toks)
                    out.append(float(m["loss"]))
            return out

        l1 = losses(None)
        mesh = make_mesh((2, 2), ("data", "model"))
        l2 = losses(mesh)
        print("L1", l1); print("L2", l2)
        np.testing.assert_allclose(l1, l2, rtol=2e-2, atol=2e-2)
        print("SHARD_OK")
    """))
    assert "SHARD_OK" in out


def test_tp_gemm_matches_reference():
    """Explicit narrow-wire TP GEMMs == plain qlinear within fp8 noise."""
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh, set_mesh
        from repro.core.policy import HFP8
        from repro.core.linear import qlinear
        from repro.parallel.sharding import make_rules
        from repro.parallel.tp_gemm import tp_column_linear, tp_row_linear
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh, seq_shard=True)
        rng = np.random.default_rng(0)
        B, S, K, N = 4, 16, 32, 64
        x = jnp.asarray(rng.normal(0, 1, (B, S, K)), jnp.bfloat16)
        w = jnp.asarray(rng.normal(0, 0.3, (K, N)), jnp.bfloat16)

        def loss_tp(x, w):
            return (tp_column_linear(x, w, HFP8, rules)
                    .astype(jnp.float32) ** 2).sum()

        def loss_ref(x, w):
            return (qlinear(x, w, HFP8, impl="xla")
                    .astype(jnp.float32) ** 2).sum()

        with set_mesh(mesh):
            vt, gt = jax.jit(jax.value_and_grad(loss_tp, (0, 1)))(x, w)
        vr, gr = jax.jit(jax.value_and_grad(loss_ref, (0, 1)))(x, w)
        assert abs(float(vt) - float(vr)) / float(vr) < 0.05, (vt, vr)
        for a, b in zip(jax.tree.leaves(gt), jax.tree.leaves(gr)):
            na = np.asarray(a, np.float32); nb = np.asarray(b, np.float32)
            denom = np.abs(nb).max() + 1e-6
            assert np.abs(na - nb).max() / denom < 0.3, \
                np.abs(na - nb).max() / denom

        # row-parallel
        h = jnp.asarray(rng.normal(0, 1, (B, S, N)), jnp.bfloat16)
        w2 = jnp.asarray(rng.normal(0, 0.3, (N, K)), jnp.bfloat16)
        def loss_tp2(h, w2):
            return (tp_row_linear(h, w2, HFP8, rules)
                    .astype(jnp.float32) ** 2).sum()
        def loss_ref2(h, w2):
            return (qlinear(h, w2, HFP8, impl="xla")
                    .astype(jnp.float32) ** 2).sum()
        with set_mesh(mesh):
            vt2, gt2 = jax.jit(jax.value_and_grad(loss_tp2, (0, 1)))(h, w2)
        vr2, gr2 = jax.jit(jax.value_and_grad(loss_ref2, (0, 1)))(h, w2)
        assert abs(float(vt2) - float(vr2)) / float(vr2) < 0.05
        print("TPGEMM_OK")
    """))
    assert "TPGEMM_OK" in out


def test_block_tp_gemm_matches_block_qlinear():
    """Block-scaled TP path ≡ single-device block-scaled qlinear within
    wire-format tolerance (fwd + grads), and proj() routes hfp8_block to
    the TP GEMM under sequence-parallel rules."""
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, set_mesh
        from repro.core.policy import get_policy
        from repro.core.linear import qlinear
        from repro.parallel.sharding import make_rules
        from repro.parallel.tp_gemm import (tp_applicable, tp_column_linear,
                                            tp_row_linear)
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh, seq_shard=True)
        pol = get_policy("hfp8_block")
        rng = np.random.default_rng(0)
        B, S, K, N = 4, 16, 32, 64
        x = jnp.asarray(rng.normal(0, 1, (B, S, K)), jnp.bfloat16)
        w = jnp.asarray(rng.normal(0, 0.3, (K, N)), jnp.bfloat16)
        assert tp_applicable(x, rules, pol)  # block policy no longer opts out

        def check(tp_fn, x, w):
            def loss_tp(x, w):
                return (tp_fn(x, w, pol, rules).astype(jnp.float32)**2).sum()
            def loss_ref(x, w):
                return (qlinear(x, w, pol, impl="xla")
                        .astype(jnp.float32) ** 2).sum()
            with set_mesh(mesh):
                vt, gt = jax.jit(jax.value_and_grad(loss_tp, (0, 1)))(x, w)
            vr, gr = jax.jit(jax.value_and_grad(loss_ref, (0, 1)))(x, w)
            assert abs(float(vt) - float(vr)) / float(vr) < 0.05, (vt, vr)
            for a, b in zip(jax.tree.leaves(gt), jax.tree.leaves(gr)):
                na = np.asarray(a, np.float32)
                nb = np.asarray(b, np.float32)
                rel = np.abs(na - nb).max() / (np.abs(nb).max() + 1e-6)
                assert rel < 0.3, rel

        check(tp_column_linear, x, w)
        h = jnp.asarray(rng.normal(0, 1, (B, S, N)), jnp.bfloat16)
        w2 = jnp.asarray(rng.normal(0, 0.3, (N, K)), jnp.bfloat16)
        check(tp_row_linear, h, w2)

        # proj() routing: with hfp8_block + seq-parallel rules the block
        # path goes through the TP GEMM, not GSPMD qlinear
        import repro.models.layers as L
        hits = []
        orig = L.tp_column_linear
        def spy(*a, **k):
            hits.append(1)
            return orig(*a, **k)
        L.tp_column_linear = spy
        try:
            with set_mesh(mesh):
                y = jax.jit(lambda x, w: L.proj(
                    x, w, None, pol, rules, "xla", kind="col"))(x, w)
        finally:
            L.tp_column_linear = orig
        assert hits, "proj() did not route hfp8_block to the TP GEMM"
        assert y.shape == (B, S, N)
        print("BLOCKTP_OK")
    """))
    assert "BLOCKTP_OK" in out


def test_mx_tp_gemm_bit_exact_vs_single_device():
    """MX over the explicit TP wire (DESIGN.md §9): fwd/dgrad/wgrad of
    the column- and row-parallel MX GEMMs are BIT-EXACT against the
    single-device mxfp8 qlinear (ops.mx_gemm) on exact-arithmetic
    operands — small-int activations, one-hot weight columns, a
    2-token-support cotangent, so every quantize/dequant (including
    the wire's own E8M0 re-grouping) and every f32 partial sum is
    exact — and proj() routes mxfp8 onto the TP wire."""
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, set_mesh
        from repro.core.policy import get_policy
        from repro.core.linear import qlinear
        from repro.parallel.sharding import make_rules
        from repro.parallel.tp_gemm import (tp_applicable, tp_column_linear,
                                            tp_row_linear)
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh, seq_shard=True)
        pol = get_policy("mxfp8")
        B, S, K, N = 4, 32, 64, 128
        rng = np.random.default_rng(7)
        x = rng.integers(-2, 3, (B, S, K)).astype(np.float32)
        assert tp_applicable(jnp.asarray(x), rules, pol)
        w = np.zeros((K, N), np.float32)
        for n in range(N):
            w[n % K, n] = rng.choice([-2.0, -1.0, 1.0, 2.0])
        g = np.zeros((B, S, N), np.float32)
        for (b, s) in [(0, 3), (2, 17)]:
            g[b, s] = rng.choice([-1.0, 0.0, 1.0], N)

        def check(tp_fn, x, w, g):
            xj = jnp.asarray(x, jnp.bfloat16)
            wj = jnp.asarray(w, jnp.bfloat16)
            gj = jnp.asarray(g, jnp.bfloat16)
            def tp(x, w):
                with set_mesh(mesh):
                    y, vjp = jax.vjp(
                        lambda x, w: tp_fn(x, w, pol, rules), x, w)
                    return (y,) + vjp(gj)
            def sd(x, w):
                y, vjp = jax.vjp(
                    lambda x, w: qlinear(x, w, pol, impl="xla"), x, w)
                return (y,) + vjp(gj)
            got = jax.jit(tp)(xj, wj)
            want = jax.jit(sd)(xj, wj)
            for name, a, b in zip(("y", "dx", "dw"), got, want):
                np.testing.assert_array_equal(
                    np.asarray(a, np.float32), np.asarray(b, np.float32),
                    err_msg=name)

        check(tp_column_linear, x, w, g)

        # row-parallel: one nonzero per weight column (injective map)
        x2 = rng.integers(-2, 3, (B, S, N)).astype(np.float32)
        w2 = np.zeros((N, K), np.float32)
        perm = rng.permutation(N)[:K]
        for k in range(K):
            w2[perm[k], k] = rng.choice([-2.0, -1.0, 1.0, 2.0])
        g2 = np.zeros((B, S, K), np.float32)
        for (b, s) in [(1, 5), (3, 30)]:
            g2[b, s] = rng.choice([-1.0, 0.0, 1.0], K)
        check(tp_row_linear, x2, w2, g2)

        # proj() routes mxfp8 onto the explicit TP wire
        import repro.models.layers as L
        hits = []
        orig = L.tp_column_linear
        def spy(*a, **k):
            hits.append(1)
            return orig(*a, **k)
        L.tp_column_linear = spy
        try:
            with set_mesh(mesh):
                y = jax.jit(lambda x, w: L.proj(
                    x, w, None, pol, rules, "xla", kind="col"))(
                    jnp.asarray(x, jnp.bfloat16), jnp.asarray(w, jnp.bfloat16))
        finally:
            L.tp_column_linear = orig
        assert hits, "proj() did not route mxfp8 to the TP GEMM"
        assert y.shape == (B, S, N)
        print("MXTP_OK")
    """))
    assert "MXTP_OK" in out


def test_moe_ep_matches_reference():
    """shard_map expert-parallel MoE == einsum dispatch reference."""
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, set_mesh
        from repro.configs import ARCHS
        from repro.core.policy import get_policy
        from repro.models import moe as MOE
        from repro.parallel.sharding import make_rules
        cfg = dataclasses.replace(
            ARCHS["granite-moe-3b-a800m"].reduced(),
            n_experts=6, top_k=2, capacity_factor=8.0)  # high cap: no drops
        policy = get_policy("bf16")  # isolate dispatch math from fp8 noise
        rng = np.random.default_rng(0)
        params = MOE.init_moe(jax.random.key(0), cfg, jnp.bfloat16)
        x = jnp.asarray(rng.normal(0, 1, (4, 8, cfg.d_model)), jnp.bfloat16)
        y_ref, aux_ref = jax.jit(lambda p, v: MOE.moe_ffn(
            v, p, cfg, policy, rules=None, impl="xla"))(params, x)
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh, seq_shard=True)
        with set_mesh(mesh):
            y_ep, aux_ep = jax.jit(lambda p, v: MOE.moe_ffn_ep(
                v, p, cfg, policy, rules=rules, impl="xla"))(params, x)
        np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=0.05, atol=0.05)
        assert abs(float(aux_ep["loss"]) - float(aux_ref["loss"])) < 1e-3
        # aux is a metrics dict on both paths; capacity_factor=8 with the
        # t_loc*k clamp means nothing drops on either
        for aux in (aux_ref, aux_ep):
            assert set(aux) == {"loss", "drop_frac", "capacity"}, aux
            assert float(aux["drop_frac"]) == 0.0, aux
        # EP capacity is clamped to the local token supply: t_loc=16, k=2
        assert float(aux_ep["capacity"]) <= 16 * 2, aux_ep
        print("MOEEP_OK")
    """))
    assert "MOEEP_OK" in out


def test_mx_dp_wire_bit_exact_vs_oracle_8dev():
    """The packed MX gradient wire (DESIGN.md §13) on a real 8-way data
    axis is BIT-EXACT against the numpy oracle: per-source
    exact-arithmetic operands (span=8 keeps every 8-source f32 partial
    sum exact) with one poisoned group — reduced mean AND per-source
    new error feedback match ``compressed_mean_mx_ref`` element for
    element, NaN poison included."""
    out = _run(textwrap.dedent("""
        import os, sys, functools
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        sys.path.insert(0, "tests")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core.formats import get_mx_format
        from repro.kernels.ref import compressed_mean_mx_ref
        from fuzz import exact_mx_operands

        mesh = make_mesh((8,), ("data",))
        for name in ("mxfp6e3m2", "mxfp4e2m1"):
            mx = get_mx_format(name)
            rng = np.random.default_rng(3)
            a, _ = exact_mx_operands(rng, 8, 256, 1, mx, span=8)
            g_all = a.astype(np.float32)       # row i = source replica i
            sh = NamedSharding(mesh, P("data", None))
            gd = jax.device_put(jnp.asarray(g_all), sh)
            ed = jax.device_put(jnp.zeros_like(gd), sh)

            @jax.jit
            @functools.partial(shard_map, mesh=mesh,
                               in_specs=(P("data", None), P("data", None)),
                               out_specs=(P("data", None), P("data", None)),
                               check_vma=False)
            def run(g, e, mx=mx):
                from repro.optim.grad_compress import _leaf_mx
                red, ne = _leaf_mx(g[0], e[0], mx, "data", 8, 4)
                return red[None], ne[None]

            red, ne = run(gd, ed)
            want, want_efs = compressed_mean_mx_ref(
                [g_all[i] for i in range(8)],
                [np.zeros(256, np.float32)] * 8, mx=name)
            assert not np.all(np.isfinite(want))   # poison reached output
            for d in range(8):
                np.testing.assert_array_equal(
                    np.asarray(red)[d], want, err_msg=f"{name} red dev{d}")
                np.testing.assert_array_equal(
                    np.asarray(ne)[d], want_efs[d],
                    err_msg=f"{name} ef dev{d}")
        print("MXDP_ORACLE_OK")
    """))
    assert "MXDP_ORACLE_OK" in out


def test_mx_dispatch_a2a_bit_exact_vs_oracle():
    """The MoE packed dispatch wire: fwd AND vjp of ``mx_dispatch_a2a``
    on a 4-way model axis are bit-exact against the numpy roundtrip
    oracle composed with the a2a block permutation (tiled split-0 /
    concat-0: out[i, j] = in[j, i] per row block).  The bwd hop uses
    the wide bwd format, checked independently."""
    out = _run(textwrap.dedent("""
        import os, sys, functools
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        sys.path.insert(0, "tests")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core.formats import get_mx_format
        from repro.kernels.ref import mx_dispatch_wire_ref
        from repro.parallel.tp_gemm import mx_dispatch_a2a
        from fuzz import exact_mx_operands

        tp, R, d = 4, 8, 64
        mx_f, mx_b = "mxfp6e3m2", "mxfp8e5m2"
        mxf = get_mx_format(mx_f)
        rng = np.random.default_rng(11)
        x, _ = exact_mx_operands(rng, tp * tp * R, d, 1, mxf, span=8)
        g, _ = exact_mx_operands(rng, tp * tp * R, d, 1,
                                 get_mx_format(mx_b), span=8,
                                 specials=False)
        X = x.astype(np.float32).reshape(tp, tp * R, d)
        G = g.astype(np.float32).reshape(tp, tp * R, d)
        mesh = make_mesh((tp,), ("model",))
        sh = NamedSharding(mesh, P("model", None, None))
        xd = jax.device_put(jnp.asarray(X), sh)
        gd = jax.device_put(jnp.asarray(G), sh)

        @jax.jit
        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P("model", None, None),) * 2,
                           out_specs=(P("model", None, None),) * 2,
                           check_vma=False)
        def run(xl, gl):
            y, vjp = jax.vjp(lambda v: mx_dispatch_a2a(
                v, "model", get_mx_format("mxfp6e3m2"),
                get_mx_format("mxfp8e5m2")), xl[0])
            (dx,) = vjp(gl[0])
            return y[None], dx[None]

        y, dx = run(xd, gd)
        perm = lambda A: (A.reshape(tp, tp, R, d).transpose(1, 0, 2, 3)
                          .reshape(tp, tp * R, d))
        want_y = perm(mx_dispatch_wire_ref(X, mx=mx_f))
        want_dx = perm(mx_dispatch_wire_ref(G, mx=mx_b))
        assert not np.all(np.isfinite(want_y))   # poison group survives
        np.testing.assert_array_equal(np.asarray(y), want_y, err_msg="fwd")
        np.testing.assert_array_equal(np.asarray(dx), want_dx,
                                      err_msg="bwd")
        print("MXA2A_ORACLE_OK")
    """))
    assert "MXA2A_ORACLE_OK" in out


def test_moe_ep_packed_wire_matches_einsum():
    """EP MoE with an MX policy routes both dispatch all-to-alls through
    the packed wire (spied) and still matches the einsum reference
    within wire-format tolerance; a group-misaligned d_model refuses the
    wire and falls back to the raw bf16 a2a."""
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, set_mesh
        from repro.configs import ARCHS
        from repro.core.policy import get_policy
        from repro.models import moe as MOE
        import repro.parallel.tp_gemm as TPG
        from repro.parallel.sharding import make_rules

        cfg = dataclasses.replace(
            ARCHS["granite-moe-3b-a800m"].reduced(),
            n_experts=6, top_k=2, capacity_factor=8.0)
        assert cfg.d_model % 32 == 0    # group-aligned: wire eligible
        policy = get_policy("mxfp8")
        rng = np.random.default_rng(0)
        params = MOE.init_moe(jax.random.key(0), cfg, jnp.bfloat16)
        x = jnp.asarray(rng.normal(0, 1, (4, 8, cfg.d_model)), jnp.bfloat16)
        y_ref, aux_ref = jax.jit(lambda p, v: MOE.moe_ffn(
            v, p, cfg, policy, rules=None, impl="xla"))(params, x)

        mesh = make_mesh((2, 4), ("data", "model"))
        rules = make_rules(mesh, seq_shard=True)
        hits = []
        orig = TPG.mx_dispatch_a2a
        def spy(*a, **k):
            hits.append(1)
            return orig(*a, **k)
        TPG.mx_dispatch_a2a = spy
        try:
            with set_mesh(mesh):
                y_ep, aux_ep = jax.jit(lambda p, v: MOE.moe_ffn_ep(
                    v, p, cfg, policy, rules=rules, impl="xla"))(params, x)
        finally:
            TPG.mx_dispatch_a2a = orig
        assert len(hits) >= 2, "both a2a hops should take the packed wire"
        # the EP path quantizes the dispatch buffer through the wire on
        # top of the GEMM quantization both paths share -> slightly
        # wider band than the bf16-wire parity test
        np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=0.05, atol=0.12)
        assert abs(float(aux_ep["loss"]) - float(aux_ref["loss"])) < 2e-3
        assert float(aux_ep["drop_frac"]) == 0.0, aux_ep

        # misaligned d_model (40 % 32 != 0): bf16 fallback, wire unused
        cfg_mis = dataclasses.replace(cfg, d_model=40, d_ff=80)
        params_mis = MOE.init_moe(jax.random.key(1), cfg_mis, jnp.bfloat16)
        x_mis = jnp.asarray(rng.normal(0, 1, (4, 8, 40)), jnp.bfloat16)
        hits2 = []
        TPG.mx_dispatch_a2a = (lambda *a, **k:
                               (hits2.append(1), orig(*a, **k))[1])
        try:
            with set_mesh(mesh):
                y_mis, _ = jax.jit(lambda p, v: MOE.moe_ffn_ep(
                    v, p, cfg_mis, policy, rules=rules, impl="xla"))(
                    params_mis, x_mis)
        finally:
            TPG.mx_dispatch_a2a = orig
        assert not hits2, "misaligned d_model must not take the MX wire"
        assert np.all(np.isfinite(np.asarray(y_mis, np.float32)))
        print("MOEMX_OK")
    """))
    assert "MOEMX_OK" in out


def test_dp_compress_train_step_matches_uncompressed():
    """``make_train_step(dp_compress=True)`` trains a real mxfp6 model
    over the compressed DP wire (``Policy.mx_dp_grad`` = mxfp6e3m2):
    losses track the uncompressed run, nothing skips, and the error
    feedback picks up the (real, nonzero) quantization residual."""
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, set_mesh
        from repro.configs.base import ModelConfig
        from repro.models import build_model
        from repro.optim.adamw import AdamWConfig
        from repro.parallel.sharding import make_rules
        from repro.train.train_step import make_train_state, make_train_step

        cfg = ModelConfig(name="dpc", family="dense", n_layers=1,
            d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
            vocab_size=64, head_dim=32, policy_name="mxfp6",
            attn_q_chunk=32)
        mesh = make_mesh((4,), ("data",))
        rules = make_rules(mesh)
        model = build_model(cfg)
        opt = AdamWConfig(lr=1e-3, warmup_steps=1, schedule="constant")
        toks = jnp.asarray(np.random.default_rng(0).integers(0, 64, (4, 32)))

        def losses(dp_compress):
            state = make_train_state(model, jax.random.key(0), opt,
                                     dp_compress=dp_compress)
            step = jax.jit(make_train_step(model, opt, rules=rules,
                                           impl="xla",
                                           dp_compress=dp_compress))
            out = []
            with set_mesh(mesh):
                for _ in range(3):
                    state, m = step(state, toks)
                    out.append(float(m["loss"]))
                    assert int(m["skipped"]) == 0
            return out, state

        lc, sc = losses(True)
        lu, su = losses(False)
        assert "ef" in sc and "ef" not in su
        assert all(np.isfinite(lc)), lc
        np.testing.assert_allclose(lc, lu, rtol=0.05, atol=0.05)
        ef_norm = sum(float(jnp.abs(e).sum())
                      for e in jax.tree.leaves(sc["ef"]))
        assert ef_norm > 0, "mxfp6 residual should land in the ef tree"
        print("COMPRESSED", lc, "PLAIN", lu)
        print("DPC_OK")
    """))
    assert "DPC_OK" in out


def test_elastic_restore_onto_mesh():
    """A checkpoint written layout-free restores onto a (2,2) mesh with
    explicit shardings — the elastic-scaling path (save on N chips,
    resume on M)."""
    out = _run(textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh
        from repro.checkpoint.ckpt import CheckpointManager
        from repro.configs import ARCHS
        from repro.models import build_model
        from repro.parallel.sharding import param_pspecs

        cfg = ARCHS["llama3.2-3b"].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save(7, params)                      # "saved on 1 chip"

        mesh = make_mesh((2, 2), ("data", "model"))
        pspecs = param_pspecs(jax.eval_shape(lambda: params), mesh)
        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: type(x).__name__ == "PartitionSpec")
        back = mgr.restore(7, params, shardings)  # "resumed on 4 chips"
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert len(b.sharding.device_set) >= 1
        # at least the big 2D params actually ended up distributed
        emb = back["embed"]
        assert len(emb.sharding.device_set) == 4, emb.sharding
        print("ELASTIC_OK")
    """))
    assert "ELASTIC_OK" in out


def test_mxfp6_train_step_tp_matches_gspmd():
    """mxfp6 (DESIGN.md §10) runs a real train step through
    models/layers.py on BOTH distribution paths: sequence-parallel
    rules route the group-aligned projections onto the explicit TP
    wire (packed sub-byte payloads + E8M0 byte grids — asserted via a
    proj() spy), plain rules keep them under GSPMD over the packed MX
    pipeline, and the two agree on the losses."""
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, set_mesh
        from repro.configs.base import ModelConfig
        from repro.models import build_model
        import repro.models.layers as L
        from repro.optim.adamw import AdamWConfig
        from repro.parallel.sharding import make_rules
        from repro.train.train_step import make_train_state, make_train_step

        cfg = ModelConfig(
            name="sub-byte-mxfp6", family="dense", n_layers=1,
            d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
            vocab_size=64, head_dim=32, policy_name="mxfp6",
            attn_q_chunk=32)
        mesh = make_mesh((2, 2), ("data", "model"))
        model = build_model(cfg)
        opt = AdamWConfig(lr=1e-3, warmup_steps=1, schedule="constant")
        toks = jnp.asarray(np.random.default_rng(0).integers(0, 64, (4, 32)))

        def losses(rules, steps=2):
            state = make_train_state(model, jax.random.key(0), opt)
            step = jax.jit(make_train_step(model, opt, rules=rules,
                                           impl="xla"))
            out = []
            with set_mesh(mesh):
                for _ in range(steps):
                    state, m = step(state, toks)
                    out.append(float(m["loss"]))
            return out

        hits = []
        orig = L.tp_column_linear
        L.tp_column_linear = (lambda *a, **k:
                              (hits.append(1), orig(*a, **k))[1])
        try:
            l_tp = losses(make_rules(mesh, seq_shard=True))
        finally:
            L.tp_column_linear = orig
        assert hits, "proj() did not route mxfp6 to the TP wire"
        l_g = losses(make_rules(mesh))
        assert all(np.isfinite(l_tp)) and all(np.isfinite(l_g))
        np.testing.assert_allclose(l_tp, l_g, rtol=0.05, atol=0.05)
        print("TP", l_tp, "GSPMD", l_g)
        print("MXFP6_TP_OK")
    """))
    assert "MXFP6_TP_OK" in out


def test_mxfp4_train_step_and_misaligned_fallback():
    """mxfp4 takes the explicit TP wire on group-aligned shapes and
    trains (finite losses); a group-MISALIGNED model (seq % 32 != 0)
    refuses the wire — proj() spy never fires — and still trains via
    the GSPMD fallback."""
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, set_mesh
        from repro.configs.base import ModelConfig
        from repro.models import build_model
        import repro.models.layers as L
        from repro.optim.adamw import AdamWConfig
        from repro.parallel.sharding import make_rules
        from repro.train.train_step import make_train_state, make_train_step

        def run(seq):
            cfg = ModelConfig(
                name="sub-byte-mxfp4", family="dense", n_layers=1,
                d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                vocab_size=64, head_dim=32, policy_name="mxfp4",
                attn_q_chunk=seq)
            mesh = make_mesh((2, 2), ("data", "model"))
            model = build_model(cfg)
            opt = AdamWConfig(lr=1e-3, warmup_steps=1, schedule="constant")
            state = make_train_state(model, jax.random.key(0), opt)
            rules = make_rules(mesh, seq_shard=True)
            step = jax.jit(make_train_step(model, opt, rules=rules,
                                           impl="xla"))
            toks = jnp.asarray(
                np.random.default_rng(0).integers(0, 64, (4, seq)))
            hits = []
            orig = L.tp_column_linear
            L.tp_column_linear = (lambda *a, **k:
                                  (hits.append(1), orig(*a, **k))[1])
            try:
                with set_mesh(mesh):
                    losses = []
                    for _ in range(2):
                        state, m = step(state, toks)
                        losses.append(float(m["loss"]))
            finally:
                L.tp_column_linear = orig
            return losses, bool(hits)

        l_ok, wired = run(32)          # seq 32: whole groups -> TP wire
        assert wired, "aligned mxfp4 did not take the TP wire"
        assert all(np.isfinite(l_ok)), l_ok
        l_mis, wired_mis = run(24)     # seq 24: no whole groups
        assert not wired_mis, "misaligned shapes took the wire"
        assert all(np.isfinite(l_mis)), l_mis
        print("OK", l_ok, "MIS", l_mis)
        print("MXFP4_TP_OK")
    """))
    assert "MXFP4_TP_OK" in out
