"""MX-format emulation (DESIGN.md §8): shared-exponent groups of 32.

Three layers, mirroring test_blockscale.py:

1. the numpy group-quantization oracle (``mx_quantize_np`` /
   ``mx_group_scales_np`` / E8M0 encode-decode) is validated against
   native ml_dtypes casts and its own invariants;
2. the JAX scale computation and the fused Pallas kernels (interpret
   mode) must match the oracle **bit for bit** — quantization is
   elementwise after the per-group amax, so this holds on arbitrary
   float data; the GEMM is checked bit-exactly on data constructed so
   fp32 accumulation is exact (integer grids × per-group pow2
   magnitudes, incl. a tile with per-group dynamic range 2^16 and a
   non-finite group);
3. the ``mxfp8`` policy end-to-end: fwd/bwd finite, close to per-tensor
   hfp8 on well-scaled data, far better on fine-grained outliers.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import fuzz
from repro.core import formats as F
from repro.core.scaling import apply_group_scales, compute_group_scales
from repro.kernels import ops

MX_NAMES = list(F.MX_FORMATS)


# ------------------------------------------------------------- constants --

def test_mx_format_constants():
    for name, mx in F.MX_FORMATS.items():
        assert mx.group == 32
        assert F.get_mx_format(name) is mx
    assert F.MXFP8E4M3.elem is F.FP8ALT and F.MXFP8E5M2.elem is F.FP8
    # OCP element max normals (no-specials formats spend the top
    # exponent code on normals)
    assert F.MXFP4E2M1.elem.max_normal == 6.0
    assert F.MXFP6E2M3.elem.max_normal == 7.5
    assert F.MXFP6E3M2.elem.max_normal == 28.0
    assert not F.FP4E2M1.ieee_specials and F.FP4E2M1.width == 4
    # 8 scale bits amortized over the group
    assert F.MXFP4E2M1.bits_per_element == 4 + 8 / 32


def test_e8m0_encode_decode():
    exps = np.arange(-126, 128)
    s = np.ldexp(1.0, exps)
    code = F.e8m0_encode_np(s)
    np.testing.assert_array_equal(code, exps + F.E8M0_BIAS)
    np.testing.assert_array_equal(F.e8m0_decode_np(code), s)
    # NaN round-trips through the 0xFF encoding
    assert F.e8m0_encode_np(np.asarray([np.nan]))[0] == F.E8M0_NAN
    assert np.isnan(F.e8m0_decode_np(np.asarray([F.E8M0_NAN]))[0])
    # non-pow2 input is a contract violation
    with pytest.raises(AssertionError):
        F.e8m0_encode_np(np.asarray([3.0]))


def test_e8m0_matches_native_ml_dtype():
    import ml_dtypes
    if not hasattr(ml_dtypes, "float8_e8m0fnu"):
        pytest.skip("ml_dtypes too old for float8_e8m0fnu")
    s = np.ldexp(1.0, np.arange(-126, 128)).astype(np.float32)
    native = s.astype(ml_dtypes.float8_e8m0fnu).astype(np.float32)
    np.testing.assert_array_equal(s, native)  # pow2 scales are exact
    codes = F.e8m0_encode_np(s)
    np.testing.assert_array_equal(
        codes, s.astype(ml_dtypes.float8_e8m0fnu).view(np.uint8))


# ----------------------------------------------------------- oracle layer --

@pytest.mark.parametrize("name", MX_NAMES)
def test_oracle_scale_invariants(name):
    mx = F.get_mx_format(name)
    x = fuzz.group_structured(np.random.default_rng(21), 8, 128, mx.group)
    s = F.mx_group_scales_np(x, mx)
    assert s.shape == (8, 128 // mx.group)
    assert s[0, 0] == 1.0                      # all-zero group -> neutral
    assert np.isnan(s[1, 1]) and np.isnan(s[2, 2])  # non-finite -> NaN scale
    fin = np.isfinite(s)
    lg = np.log2(s[fin])
    assert (lg == np.round(lg)).all()          # pow2-only, no mantissa
    assert (s[fin] >= 2.0 ** -126).all() and (s[fin] <= 2.0 ** 127).all()
    # scaled amax fills (half, full] of the element range
    amax = np.abs(x).reshape(8, -1, mx.group).max(-1)
    ok = np.isfinite(amax) & (amax > 0)
    filled = amax[ok] / s[ok]
    assert (filled <= mx.elem.max_normal).all()
    assert (filled > mx.elem.max_normal / 2).all()


@pytest.mark.parametrize("name", MX_NAMES)
def test_oracle_roundtrip_error_bound(name):
    """|x - deq(q(x))| <= 2^-man * group_amax for finite groups — the
    shared exponent bounds error by the *group* amax, not the tensor's."""
    mx = F.get_mx_format(name)
    x = fuzz.group_structured(np.random.default_rng(22), 16, 256, mx.group,
                              specials=False)
    q, s = F.mx_quantize_np(x, mx)
    back = F.mx_dequantize_np(q, s, mx)
    err = np.abs(back - x.astype(np.float64))
    amax = np.abs(x).reshape(16, -1, mx.group).max(-1)
    bound = np.repeat(amax, mx.group, 1) * 2.0 ** (-mx.elem.man_bits) * 1.01
    assert (err <= bound).all()


def test_oracle_nan_group_poisons_whole_group():
    x = fuzz.group_structured(np.random.default_rng(23), 4, 96, 32)
    q, s = F.mx_quantize_np(x, "mxfp4e2m1")
    back = F.mx_dequantize_np(q, s, "mxfp4e2m1")
    assert np.isnan(back[1, 32:64]).all()      # inf element's whole group
    assert np.isnan(back[2, 64:]).all()        # NaN element's whole group
    clean = np.isfinite(s)
    assert np.isfinite(back.reshape(4, 3, 32)[clean]).all()


# ------------------------------------------------- JAX scales == oracle ----

@pytest.mark.parametrize("name", MX_NAMES)
def test_compute_group_scales_matches_oracle(name):
    mx = F.get_mx_format(name)
    x = fuzz.group_structured(np.random.default_rng(24), 8, 256, mx.group,
                              emax=20)
    want = F.mx_group_scales_np(x, mx)
    got = np.asarray(compute_group_scales(
        jnp.asarray(x), mx.group, mx.elem.max_normal))
    np.testing.assert_array_equal(got, want.astype(np.float32))
    # nan_scale=False falls back to the f32-path neutral-scale convention
    got2 = np.asarray(compute_group_scales(
        jnp.asarray(x), mx.group, mx.elem.max_normal, nan_scale=False))
    assert np.isfinite(got2).all()
    np.testing.assert_array_equal(got2[np.isfinite(want)],
                                  want[np.isfinite(want)].astype(np.float32))
    assert (got2[~np.isfinite(want)] == 1.0).all()


def test_apply_group_scales_exact_inverse():
    x = jnp.asarray(fuzz.group_structured(np.random.default_rng(25), 4, 128,
                                          32, specials=False))
    s = compute_group_scales(x, 32, 240.0)
    y = apply_group_scales(apply_group_scales(x, s, 32, inverse=True), s, 32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))  # pow2 exact


# ------------------------------------------- fused quantize kernel --------

@pytest.mark.parametrize("name", MX_NAMES)
@pytest.mark.parametrize("impl", ["pallas_interpret", "xla"])
def test_mx_quantize_bit_exact_vs_oracle(name, impl):
    """Arbitrary float data: quantization is elementwise after the group
    amax, so kernel == numpy oracle bit for bit — including the all-zero
    group (neutral scale), the inf group and the NaN group (E8M0 NaN
    scale poisons exactly those groups)."""
    mx = F.get_mx_format(name)
    x = fuzz.group_structured(np.random.default_rng(26), 24, 160, mx.group)
    qo, so = F.mx_quantize_np(x, mx)
    q, s = ops.mx_quantize(jnp.asarray(x), name, impl=impl)
    np.testing.assert_array_equal(np.asarray(s), so.astype(np.float32))
    np.testing.assert_array_equal(np.asarray(q, np.float64), qo)


def test_mx_quantize_ragged_and_batched():
    """Non-multiple M pads inside the wrapper; leading dims are batch."""
    x = jnp.asarray(fuzz.group_structured(np.random.default_rng(27), 10,
                                          64, 32, specials=False))
    q, s = ops.mx_quantize(x, "mxfp8e4m3", impl="pallas_interpret")
    assert q.shape == (10, 64) and s.shape == (10, 2)
    q2, s2 = ops.mx_quantize(x, "mxfp8e4m3", impl="xla")
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))
    x3 = jnp.stack([x, 2 * x])
    q3, s3 = ops.mx_quantize(x3, "mxfp8e4m3", impl="pallas_interpret")
    assert q3.shape == (2, 10, 64) and s3.shape == (2, 10, 2)
    np.testing.assert_array_equal(np.asarray(q3[0]), np.asarray(q))
    deq = np.asarray(ops.mx_dequantize(q3, s3, "mxfp8e4m3"))
    np.testing.assert_array_equal(deq[1], 2 * deq[0])  # pow2 scaling exact


# --------------------------------------------------- fused GEMM kernel ----

# exact-arithmetic operand construction lives in tests/fuzz.py so the
# codec harness (test_codec.py) shares the same generator
_exact_mx_operands = fuzz.exact_mx_operands


def _oracle_mx_gemm(a, b, mx_a, mx_b, out_fmt):
    """numpy oracle: group-quantize both operands, dequantize exactly,
    accumulate in f64 (== f32 when construction is exact), round once."""
    qa, sa = F.mx_quantize_np(a, mx_a)
    qbt, sbt = F.mx_quantize_np(np.asarray(b).T, mx_b)   # B groups along K
    af = F.mx_dequantize_np(qa, sa, mx_a)
    bf = F.mx_dequantize_np(qbt, sbt, mx_b).T
    with np.errstate(all="ignore"):
        acc = af @ bf
    return F.quantize_np(acc, out_fmt)


@pytest.mark.parametrize("name", MX_NAMES)
@pytest.mark.parametrize("impl", ["pallas_interpret", "xla"])
def test_mx_gemm_bit_exact_vs_oracle(name, impl):
    """The acceptance-criteria workload: all five formats, per-group
    dynamic range 2^16 inside one tile, a non-finite group, multiple
    K-tiles of accumulation — kernel == oracle bit for bit (NaN rows
    positionally equal)."""
    mx = F.get_mx_format(name)
    m, k, n = 16, 256, 48
    a, b = _exact_mx_operands(np.random.default_rng(28), m, k, n, mx)
    want = _oracle_mx_gemm(a, b, mx, mx, "fp32")
    got = ops.mx_gemm(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
                      mx_a=name, impl=impl)
    assert got.shape == (m, n)
    np.testing.assert_array_equal(np.asarray(got, np.float64), want)
    # the poisoned row is NaN (E8M0 NaN scale propagated), others finite
    assert np.isnan(want[1]).all()
    assert np.isfinite(np.delete(want, 1, axis=0)).all()


def test_mx_gemm_mixed_formats_bit_exact():
    """fwd-style E4M3 × bwd-style E5M2 pairing, bit-exact."""
    mx_a, mx_b = F.MXFP8E4M3, F.MXFP8E5M2
    a, b = _exact_mx_operands(np.random.default_rng(29), 8, 128, 24, mx_a,
                              specials=False)
    want = _oracle_mx_gemm(a, b, mx_a, mx_b, "fp16alt")
    got = ops.mx_gemm(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
                      mx_a=mx_a, mx_b=mx_b, out_dtype=jnp.bfloat16,
                      impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(got, np.float64), want)


@pytest.mark.parametrize("shape", [(50, 96, 24), (16, 64, 8), (3, 20, 160, 40)],
                         ids=str)
def test_mx_gemm_ragged_and_batched_impls_agree(shape):
    """Arbitrary float data + ragged/batched shapes: interpret-mode
    kernel vs pure-jnp ref to f32 summation-order tolerance."""
    *lead, m, k, n = shape
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.normal(0, 4, (*lead, m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 4, (k, n)), jnp.float32)
    o_p = ops.mx_gemm(a, b, mx_a="mxfp8e4m3", impl="pallas_interpret")
    o_r = ops.mx_gemm(a, b, mx_a="mxfp8e4m3", impl="xla")
    assert o_p.shape == (*lead, m, n)
    tol = max(k * 2.0 ** -24, 1e-6)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               rtol=tol, atol=tol * np.sqrt(k))


def test_mx_gemm_batched_matches_flattened():
    """MX scales are per-row: batching == flattening, bit for bit."""
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.normal(0, 2, (3, 16, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 2, (64, 24)), jnp.float32)
    y3 = ops.mx_gemm(a, b, mx_a="mxfp8e4m3", impl="xla")
    y2 = ops.mx_gemm(a.reshape(-1, 64), b, mx_a="mxfp8e4m3", impl="xla")
    np.testing.assert_array_equal(np.asarray(y3).reshape(-1, 24),
                                  np.asarray(y2))


# ------------------------------------------------ accuracy regression -----

def test_group32_beats_per_tensor_gemm():
    """Hot rows wreck per-tensor scaling on the *clean* rows (their
    elements fall below the format's window and flush); MX group scales
    are per-row by construction, so clean rows are untouched."""
    from repro.kernels import ref
    m, k, n = 128, 256, 64
    rng = np.random.default_rng(8)
    a = rng.normal(0, 1, (m, k))
    a[:8] *= 2.0 ** 24                       # a few huge rows
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
    exact = np.asarray(a, np.float64) @ np.asarray(b, np.float64)

    def row_nmse(out):
        err = np.asarray(out, np.float64) - exact
        pw = (exact ** 2).sum(1)
        return float(np.mean((err ** 2).sum(1)[pw > 0] / pw[pw > 0]))

    e_mx = row_nmse(ops.mx_gemm(a, b, mx_a="mxfp8e4m3", impl="xla"))
    aq, sa = ops.quantize_tensor(a, jnp.float8_e4m3)
    bq, sb = ops.quantize_tensor(b, jnp.float8_e4m3)
    e_pt = row_nmse(ref.exsdotp_gemm_ref(aq, bq, sa * sb))
    assert e_mx * 10 < e_pt, (e_mx, e_pt)


def test_group32_beats_coarse_blocks_roundtrip():
    """Granularity regression on the *operand*: one hot 32-group per
    128×128 tile drags that whole tile's window up under 128×128 block
    scaling (crushing the other 16352 elements), and the whole tensor's
    under per-tensor scaling; group-32 confines the damage to the 32 hot
    elements.  Measured as round-trip NMSE over the clean elements."""
    m, k, g = 256, 256, 32
    rng = np.random.default_rng(9)
    x = rng.normal(0, 1, (m, k))
    hot = np.zeros((m, k), bool)
    for ti in range(m // 128):               # one hot group per 128×128 tile
        for tj in range(k // 128):
            i = 128 * ti + rng.integers(128)
            j = 128 * tj + g * rng.integers(128 // g)
            x[i, j:j + g] *= 2.0 ** 24
            hot[i, j:j + g] = True
    x = jnp.asarray(x, jnp.float32)
    xe = np.asarray(x, np.float64)

    def clean_nmse(back):
        err = (np.asarray(back, np.float64) - xe)[~hot]
        return float((err ** 2).sum() / (xe[~hot] ** 2).sum())

    q, s = ops.mx_quantize(x, "mxfp8e4m3", impl="xla")
    e_mx = clean_nmse(ops.mx_dequantize(q, s, "mxfp8e4m3"))
    qb, sb = ops.quantize_blockwise(x, jnp.float8_e4m3, impl="xla")
    e_blk = clean_nmse(ops.dequantize_blockwise(qb, sb))
    qt, st = ops.quantize_tensor(x, jnp.float8_e4m3)
    e_pt = clean_nmse(np.asarray(qt, np.float32) * float(st))
    assert e_mx * 10 < e_blk, (e_mx, e_blk)
    assert e_mx * 10 < e_pt, (e_mx, e_pt)
    assert e_blk <= e_pt * 1.01, (e_blk, e_pt)


# ------------------------------------------------ policy end-to-end -------

def test_mxfp8_policy_wiring():
    from repro.core.policy import get_policy
    pol = get_policy("mxfp8")
    assert pol.mx and pol.quantized
    assert pol.mx_fwd == "mxfp8e4m3" and pol.mx_bwd_name == "mxfp8e5m2"
    assert pol.block_cfg is None             # MX path, not block path
    assert pol.loss_scaling                  # E5M2 grads are narrow-range


def test_mxfp8_rides_explicit_tp_wire_when_groups_align():
    """MX policies ride the explicit TP wire (DESIGN.md §9: fp8 payloads
    + packed E8M0 byte grids on the collectives) — but only when the
    group structure survives the sharding: the feature and sequence
    dims must tile into whole groups of 32, else the GSPMD fused-GEMM
    fallback keeps the numerics."""
    import types
    from repro.core.policy import get_policy
    from repro.parallel.tp_gemm import tp_applicable
    mesh = types.SimpleNamespace(shape={"data": 2, "model": 4},
                                 axis_names=("data", "model"))
    rules = types.SimpleNamespace(mesh=mesh, seq_shard=True,
                                  model_axis="model", model_size=4,
                                  fsdp_axis="data", batch_axes=("data",))
    x = jnp.zeros((2, 8, 16))
    assert tp_applicable(x, rules, get_policy("hfp8")) is True
    assert tp_applicable(x, rules, get_policy("hfp8_block")) is True
    # K=16, S=8: groups of 32 don't tile -> GSPMD fallback
    assert tp_applicable(x, rules, get_policy("mxfp8")) is False
    # group-aligned shapes take the wire
    xa = jnp.zeros((2, 32, 64))
    assert tp_applicable(xa, rules, get_policy("mxfp8")) is True
    # sequence misaligned (wgrad groups run along tokens) -> fallback
    assert tp_applicable(jnp.zeros((2, 16, 64)), rules,
                         get_policy("mxfp8")) is False


def test_mx_tp_misaligned_w_falls_back_not_crashes():
    """tp_applicable can't see w, so shapes whose *weight* dims break
    group alignment (N/tp for col dgrad, K for row dgrad) must route to
    the GSPMD fallback in proj() — and fail fast with a clear error,
    not a cryptic trace-time assert, when the TP GEMMs are called
    directly."""
    import types
    import repro.models.layers as L
    from repro.core.policy import get_policy
    from repro.parallel.tp_gemm import (tp_applicable, tp_column_linear,
                                        tp_row_linear)
    mesh = types.SimpleNamespace(shape={"data": 2, "model": 4},
                                 axis_names=("data", "model"))
    rules = types.SimpleNamespace(mesh=mesh, seq_shard=True,
                                  model_axis="model", model_size=4,
                                  fsdp_axis="data", batch_axes=("data",))
    pol = get_policy("mxfp8")
    x = jnp.zeros((2, 32, 64), jnp.bfloat16)
    assert tp_applicable(x, rules, pol)
    # col with N/tp = 16 (not a whole group): proj takes the GSPMD path
    w_bad = jnp.zeros((64, 64), jnp.bfloat16)
    y = L.proj(x, w_bad, None, pol, rules, "xla", kind="col")
    assert y.shape == (2, 32, 64)
    with pytest.raises(ValueError, match="N/tp divisible"):
        tp_column_linear(x, w_bad, pol, rules)
    # row with K = 48 (not a whole group): same
    xr = jnp.zeros((2, 32, 128), jnp.bfloat16)
    wr_bad = jnp.zeros((128, 48), jnp.bfloat16)
    y = L.proj(xr, wr_bad, None, pol, rules, "xla", kind="row")
    assert y.shape == (2, 32, 48)
    with pytest.raises(ValueError, match="divisible"):
        tp_row_linear(xr, wr_bad, pol, rules)


def test_qlinear_mxfp8_end_to_end():
    """mxfp8 trains: fwd+bwd finite, close to per-tensor hfp8 on
    well-scaled data, and much better on group-granular outliers."""
    from repro.core.linear import qlinear
    from repro.core.policy import get_policy
    rng = np.random.default_rng(3)
    pol_m = get_policy("mxfp8")
    pol_t = get_policy("hfp8")
    x = jnp.asarray(rng.normal(0, 1, (4, 64, 128)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(0, 0.3, (128, 64)), jnp.bfloat16)

    def loss(pol):
        def f(x, w):
            return (qlinear(x, w, pol, impl="xla")
                    .astype(jnp.float32) ** 2).sum()
        return jax.jit(jax.value_and_grad(f, (0, 1)))

    vm, gm = loss(pol_m)(x, w)
    vt, _ = loss(pol_t)(x, w)
    assert np.isfinite(float(vm))
    assert all(bool(jnp.isfinite(g).all()) for g in gm)
    assert abs(float(vm) - float(vt)) / abs(float(vt)) < 0.05
    # outlier-heavy: one huge 64-token span wrecks per-tensor scaling
    # (clean tokens flush below the window), not per-row-group MX
    xo = np.asarray(x, np.float32)
    xo[0] *= 2.0 ** 24
    xo = jnp.asarray(xo, jnp.float32).astype(jnp.bfloat16)
    exact = (np.asarray(xo, np.float64).reshape(-1, 128)
             @ np.asarray(w, np.float64))
    ym = np.asarray(qlinear(xo, w, pol_m, impl="xla"),
                    np.float64).reshape(-1, 64)
    yt = np.asarray(qlinear(xo, w, pol_t, impl="xla"),
                    np.float64).reshape(-1, 64)
    pw = (exact ** 2).sum(1)
    nz = pw > 0
    em = ((ym - exact) ** 2).sum(1)[nz] / pw[nz]
    et = ((yt - exact) ** 2).sum(1)[nz] / pw[nz]
    assert em.mean() * 10 < et.mean(), (em.mean(), et.mean())


def test_mxfp8_nonfinite_reaches_loss_scale_skip():
    """A poisoned activation under mxfp8 produces non-finite grads via
    the E8M0 NaN scale, which check_and_update_scale refuses to apply."""
    from repro.core.linear import qlinear
    from repro.core.policy import get_policy
    from repro.core.scaling import check_and_update_scale, loss_scale_init
    pol = get_policy("mxfp8")
    rng = np.random.default_rng(30)
    x = jnp.asarray(rng.normal(0, 1, (2, 32, 64)), jnp.bfloat16)
    x = x.at[0, 0, 0].set(jnp.inf)
    w = jnp.asarray(rng.normal(0, 0.3, (64, 16)), jnp.bfloat16)
    g = jax.grad(lambda x, w: (qlinear(x, w, pol, impl="xla")
                               .astype(jnp.float32) ** 2).sum(),
                 argnums=1)(x, w)
    assert not bool(jnp.isfinite(g).all())
    state = loss_scale_init()
    _, new_state, skip = check_and_update_scale(state, {"w": g})
    assert bool(skip)
    assert float(new_state["scale"]) < float(state["scale"])


# ----------------------------------------- sub-byte policies (§10) --------

def test_mxfp6_mxfp4_policy_wiring():
    from repro.core.policy import get_policy
    p6 = get_policy("mxfp6")
    assert p6.mx and p6.quantized and p6.loss_scaling
    assert p6.mx_fwd == "mxfp6e2m3" and p6.mx_bwd_name == "mxfp6e3m2"
    # FP8 master wgrad: the weight-gradient GEMM runs the MXFP8 pair
    assert p6.mx_wgrad_act_name == "mxfp8e4m3"
    assert p6.mx_wgrad_grad_name == "mxfp8e5m2"
    p4 = get_policy("mxfp4")
    assert p4.mx_fwd == "mxfp4e2m1" and p4.mx_bwd_name == "mxfp8e5m2"
    assert p4.mx_wgrad_act_name == "mxfp8e4m3"
    assert p4.mx_wgrad_grad_name == "mxfp8e5m2"
    # mxfp8 defaults: wgrad falls back to the fwd/bwd pair (unchanged)
    p8 = get_policy("mxfp8")
    assert p8.mx_wgrad_act_name == "mxfp8e4m3"
    assert p8.mx_wgrad_grad_name == "mxfp8e5m2"
    for p in (p6, p4):
        assert p.block_cfg is None            # MX path, not block path


@pytest.mark.parametrize("pname,tol", [("mxfp6", 0.05), ("mxfp4", 0.35)])
def test_qlinear_sub_byte_policy_end_to_end(pname, tol):
    """mxfp6/mxfp4 run a real fwd+bwd through the packed pipeline:
    finite, and the loss lands within the element format's precision of
    the unquantized bf16 loss (E2M3 keeps ~4 significant bits, E2M1
    ~2 — hence the per-policy tolerance)."""
    from repro.core.linear import qlinear
    from repro.core.policy import get_policy
    rng = np.random.default_rng(31)
    pol = get_policy(pname)
    x = jnp.asarray(rng.normal(0, 1, (2, 64, 96)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(0, 0.3, (96, 64)), jnp.bfloat16)

    def loss(pol):
        def f(x, w):
            return (qlinear(x, w, pol, impl="xla")
                    .astype(jnp.float32) ** 2).sum()
        return jax.jit(jax.value_and_grad(f, (0, 1)))

    vq, gq = loss(pol)(x, w)
    vr, _ = loss(get_policy("bf16"))(x, w)
    assert np.isfinite(float(vq))
    assert all(bool(jnp.isfinite(g).all()) for g in gq)
    assert abs(float(vq) - float(vr)) / abs(float(vr)) < tol, (vq, vr)


def test_qlinear_sub_byte_ragged_k():
    """Ragged K (not a whole number of groups / pack units) pads and
    masks inside the packed pipeline instead of erroring."""
    from repro.core.linear import qlinear
    from repro.core.policy import get_policy
    rng = np.random.default_rng(32)
    x = jnp.asarray(rng.normal(0, 1, (3, 10, 70)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(0, 0.3, (70, 24)), jnp.bfloat16)
    for pname in ("mxfp6", "mxfp4"):
        pol = get_policy(pname)
        v, grads = jax.value_and_grad(
            lambda x, w: (qlinear(x, w, pol, impl="xla")
                          .astype(jnp.float32) ** 2).sum(), (0, 1))(x, w)
        assert np.isfinite(float(v))
        for gr, ref_arr in zip(grads, (x, w)):
            assert gr.shape == ref_arr.shape
            assert bool(jnp.isfinite(gr).all())


def test_sub_byte_policies_ride_tp_wire_when_aligned():
    """mxfp6/mxfp4 take the explicit TP wire on group-aligned shapes —
    the packed codec makes sub-byte payloads shippable (PR 4 gated them
    off for lacking a native one-byte dtype) — and fall back to GSPMD
    when the group structure doesn't survive the sharding."""
    import types
    from repro.core.policy import get_policy
    from repro.parallel.tp_gemm import tp_applicable
    mesh = types.SimpleNamespace(shape={"data": 2, "model": 4},
                                 axis_names=("data", "model"))
    rules = types.SimpleNamespace(mesh=mesh, seq_shard=True,
                                  model_axis="model", model_size=4,
                                  fsdp_axis="data", batch_axes=("data",))
    xa = jnp.zeros((2, 32, 64))
    xm = jnp.zeros((2, 8, 16))     # K=16, S=8: no whole groups
    for pname in ("mxfp6", "mxfp4"):
        pol = get_policy(pname)
        assert tp_applicable(xa, rules, pol) is True, pname
        assert tp_applicable(xm, rules, pol) is False, pname
