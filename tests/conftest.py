"""Shared test fixtures.

Provides a minimal deterministic stand-in for ``hypothesis`` when the
real package is not installed (the CI image is offline).  Property tests
then run a fixed pseudorandom parameter sweep — same invariants, fewer
shrinking conveniences.  If ``hypothesis`` is importable it is used
unchanged.
"""
import importlib.util
import sys

if importlib.util.find_spec("hypothesis") is None:
    import functools
    import inspect
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    _DEFAULT_EXAMPLES = 20

    def _given(*strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(0xE5D07)  # deterministic sweep
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strategies]
                    named = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **named, **kwargs)

            # pytest must not mistake the drawn parameters for fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            wrapper.is_hypothesis_test = True
            return wrapper

        return deco

    def _settings(**kw):
        def deco(fn):
            fn._max_examples = kw.get("max_examples", _DEFAULT_EXAMPLES)
            return fn

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.floats = _floats
    _st.booleans = _booleans
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
