"""Payload codec layer (DESIGN.md §10): one codec from HBM to MACs.

Four layers:

1. codec object invariants: shape math, storage dtype, pack alignment,
   compiled-TPU lane units;
2. **in-kernel decode ≡ numpy oracle**: ``codec.decode_lanes`` run
   *inside a Pallas kernel* (interpret mode — the same function the
   packed GEMM inlines) against ``unpack_codes_np`` + ``decode_np`` for
   every FP4 payload byte (256), every FP8 code (256), and the
   deterministic FP6 3-byte lane sample from ``tests/fuzz.py`` (all
   boundary-code quads + random lanes; the full 2^24 sweep is the
   nightly ``slow`` job in test_pack.py);
3. the packed quantize kernel emits byte-identical payloads to the
   XLA-edge pack of the value-space path, for all five MX formats;
4. the packed-ref Pallas GEMM is bit-exact vs ``ops.mx_gemm`` on
   exact-arithmetic operands, including ragged (odd M / non-group K)
   shapes, which pad-and-mask instead of erroring.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

import fuzz
from repro.core import formats as F
from repro.kernels import ops
from repro.kernels import pack as P
from repro.kernels.codec import get_codec

MX_NAMES = list(F.MX_FORMATS)
FMT_NAMES = ["fp8", "fp8alt", "fp6e2m3", "fp6e3m2", "fp4e2m1"]


# -------------------------------------------------------- codec object ----

def test_codec_table():
    for name, want in [("fp4e2m1", (4, 2, 1, 256)),
                       ("fp6e2m3", (6, 4, 3, 512)),
                       ("fp6e3m2", (6, 4, 3, 512)),
                       ("fp8", (8, 1, 1, 128)),
                       ("fp8alt", (8, 1, 1, 128))]:
        c = get_codec(name)
        assert (c.width, c.pack_align, c.word_bytes, c.lane_unit) == want, name
        assert c.elems_per_word == c.pack_align
        assert c.storage_dtype == jnp.uint8
        # lane_unit really is the packed-lane legality floor
        assert c.packed_cols(c.lane_unit) % 128 == 0
    # accepts names, formats, MX formats; caches by format
    assert get_codec("fp4e2m1") is get_codec(F.FP4E2M1)
    assert get_codec(F.MXFP4E2M1) is get_codec("fp4e2m1")
    assert get_codec(get_codec("fp8")) is get_codec("fp8")


def test_codec_shape_math():
    c4, c6 = get_codec("fp4e2m1"), get_codec("fp6e2m3")
    assert c4.packed_cols(64) == 32 and c6.packed_cols(64) == 48
    assert c4.logical_cols(32) == 64 and c6.logical_cols(48) == 64
    assert c4.pad_cols(7) == 8 and c6.pad_cols(7) == 8
    with pytest.raises(AssertionError):
        c6.packed_cols(6)      # not pack-aligned


# ------------------------------------- in-kernel decode ≡ numpy oracle ----

def _decode_in_kernel(codec, payload):
    """Run codec.decode_lanes INSIDE a Pallas kernel (interpret mode) —
    the exact code path the packed GEMM uses for its in-register
    unpack+decode."""
    def kern(p_ref, o_ref):
        o_ref[...] = codec.decode_lanes(p_ref[...])

    rows, nbytes = payload.shape
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((rows, codec.logical_cols(nbytes)),
                                       jnp.float32),
        interpret=True,
    )(jnp.asarray(payload))
    return np.asarray(out, np.float64)


def test_fp4_in_kernel_decode_all_256_bytes():
    """Every FP4 payload byte decodes in-kernel to exactly what the
    pack.py + formats numpy oracles say."""
    codec = get_codec("fp4e2m1")
    payload = np.arange(256, dtype=np.uint8).reshape(2, 128)
    got = _decode_in_kernel(codec, payload)
    want = codec.unpack_decode_np(payload)
    np.testing.assert_array_equal(got, want)
    # and the oracle is what pack.py + decode_np compose to
    np.testing.assert_array_equal(
        want, F.decode_np(P.unpack4_np(payload), F.FP4E2M1))


@pytest.mark.parametrize("name", ["fp8", "fp8alt"])
def test_fp8_in_kernel_decode_all_256_codes(name):
    codec = get_codec(name)
    payload = np.arange(256, dtype=np.uint8).reshape(2, 128)
    got = _decode_in_kernel(codec, payload)
    want = codec.unpack_decode_np(payload)
    np.testing.assert_array_equal(np.isnan(got), np.isnan(want))
    np.testing.assert_array_equal(got[~np.isnan(got)], want[~np.isnan(want)])


@pytest.mark.parametrize("name", ["fp6e2m3", "fp6e3m2"])
def test_fp6_in_kernel_decode_lane_sample(name):
    """Deterministic FP6 lane sample (every boundary-code quad + random
    lanes from tests/fuzz.py): in-kernel decode ≡ numpy oracle.  The
    exhaustive 2^24 lane sweep runs nightly (test_pack.py, slow)."""
    codec = get_codec(name)
    lanes = fuzz.fp6_lanes(np.random.default_rng(40), n=4096)
    payload = lanes.reshape(-1, 48)            # 16 lanes / 48 B per row
    got = _decode_in_kernel(codec, payload)
    want = codec.unpack_decode_np(payload)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        want, F.decode_np(P.unpack6_np(payload), codec.fmt))


@pytest.mark.parametrize("name", FMT_NAMES)
def test_encode_pack_round_trip_vs_oracle(name):
    """encode_lanes (jnp) ≡ encode_pack_np on fuzzed values incl. every
    format boundary, and decode inverts it on the representable set."""
    codec = get_codec(name)
    vals = fuzz.sample(np.random.default_rng(41), codec.fmt, 512)
    vals = vals[:codec.pad_cols(len(vals)) - codec.pack_align]  # align
    got = np.asarray(codec.encode_lanes(jnp.asarray(vals)))
    want = codec.encode_pack_np(vals)
    np.testing.assert_array_equal(got, want)
    back = codec.unpack_decode_np(want)
    rep = np.asarray(F.quantize_np(vals.astype(np.float64), codec.fmt))
    if codec.fmt.ieee_specials:
        np.testing.assert_array_equal(np.isnan(back), np.isnan(rep))
        mask = ~np.isnan(rep)
    else:
        # no-specials formats have no NaN encoding: a NaN value encodes
        # to the max-magnitude pattern (the MX group scale carries the
        # NaN instead) — decode round-trips the finite set only
        mask = np.isfinite(vals)
        assert (np.abs(back[np.isnan(vals)]) == codec.fmt.max_normal).all()
    np.testing.assert_array_equal(back[mask], rep[mask])


# ---------------------------------------- packed quantize kernel ≡ xla ----

@pytest.mark.parametrize("name", MX_NAMES)
def test_packed_quantize_kernel_matches_xla_edge_pack(name):
    """The Pallas packed quantize kernel emits byte-identical payloads
    and scale codes to the XLA-path quantize + pack — on arbitrary data
    including an all-zero group, an inf group and a NaN group."""
    x = jnp.asarray(fuzz.group_structured(np.random.default_rng(42), 24,
                                          160, 32))
    p1, s1 = ops.mx_quantize(x, name, impl="xla", packed=True)
    p2, s2 = ops.mx_quantize(x, name, impl="pallas_interpret", packed=True)
    assert p1.dtype == p2.dtype == jnp.uint8
    assert s1.dtype == s2.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    # the true footprint: width/8 bytes per element, one byte per group
    mx = F.get_mx_format(name)
    assert p1.shape == (24, 160 * mx.elem.width // 8)
    assert s1.shape == (24, 5)


# ------------------------------------------- packed GEMM ≡ ops.mx_gemm ----

@pytest.mark.parametrize("name", MX_NAMES)
@pytest.mark.parametrize("impl", ["pallas_interpret", "xla"])
def test_packed_gemm_bit_exact_vs_mx_gemm(name, impl):
    """The acceptance-criteria workload: packed-ref GEMM (in-kernel
    unpack/decode next to the E8M0 dequant) == the fused value-path
    ``ops.mx_gemm`` bit for bit on exact-arithmetic operands with
    per-group dynamic range 2^16 and a poisoned (inf/NaN) group."""
    mx = F.get_mx_format(name)
    m, k, n = 16, 128, 48
    a, b = fuzz.exact_mx_operands(np.random.default_rng(43), m, k, n, mx)
    aj = jnp.asarray(a, jnp.float32)
    bj = jnp.asarray(b, jnp.float32)
    want = ops.mx_gemm(aj, bj, mx_a=name, impl="xla")
    ap, sa8 = ops.mx_quantize(aj, name, impl="xla", packed=True)
    bp, sb8 = ops.mx_quantize(bj.T, name, impl="xla", packed=True)
    got = ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a=name, impl=impl)
    assert got.shape == (m, n)
    np.testing.assert_array_equal(np.asarray(want, np.float64),
                                  np.asarray(got, np.float64))
    assert np.isnan(np.asarray(want)[1]).all()   # poison row survives


def test_packed_gemm_mixed_formats_batched():
    """E2M3 acts × E5M2 grads (the mxfp6 dgrad pairing) from packed
    storage, with leading batch dims, bit-exact vs the value path."""
    mx_a, mx_b = F.MXFP6E2M3, F.MXFP8E5M2
    rng = np.random.default_rng(44)
    a = jnp.asarray(rng.integers(-2, 3, (3, 8, 64)), jnp.float32)
    b = jnp.asarray(rng.integers(-2, 3, (64, 24)), jnp.float32)
    want = ops.mx_gemm(a, b, mx_a=mx_a, mx_b=mx_b, impl="xla")
    ap, sa8 = ops.mx_quantize(a, mx_a, impl="xla", packed=True)
    bp, sb8 = ops.mx_quantize(b.T, mx_b, impl="xla", packed=True)
    for impl in ("xla", "pallas_interpret"):
        got = ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a=mx_a, mx_b=mx_b,
                                 impl=impl)
        assert got.shape == (3, 8, 24)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# -------------------------------------------------- ragged shapes (§10) ----

@pytest.mark.parametrize("name", MX_NAMES)
@pytest.mark.parametrize("shape", [(10, 70, 24), (7, 33, 8)], ids=str)
def test_packed_pipeline_ragged_m_and_k(name, shape):
    """Shapes not divisible by the group / pack unit pad-and-mask inside
    the packed path: quantize pads K to whole groups (zero payload,
    neutral scale — exactly what ``ops.mx_gemm``'s own padding does),
    the GEMM's padded contributions are identically zero, and
    ``mx_unpack(k=...)`` slices the logical tail back.  Bit-exact vs
    the fused value path on small-int (exact-arithmetic) operands."""
    m, k, n = shape
    rng = np.random.default_rng(45)
    a = jnp.asarray(rng.integers(-2, 3, (m, k)), jnp.float32)
    b = jnp.asarray(rng.integers(-2, 3, (k, n)), jnp.float32)
    want = ops.mx_gemm(a, b, mx_a=name, impl="xla")   # pads K internally
    ap, sa8 = ops.mx_quantize(a, name, impl="xla", packed=True)
    bp, sb8 = ops.mx_quantize(b.T, name, impl="xla", packed=True)
    mx = F.get_mx_format(name)
    kg = k + (-k) % mx.group
    assert sa8.shape == (m, kg // mx.group)
    assert ap.shape == (m, kg * mx.elem.width // 8)
    for impl in ("xla", "pallas_interpret"):
        got = ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a=name, impl=impl)
        assert got.shape == (m, n)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    # the lossless round trip, sliced back to the logical K
    back = ops.mx_dequantize_packed(ap, sa8, name, k=k)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(a))


@pytest.mark.parametrize("impl", ["pallas_interpret", "xla"])
def test_packed_quantize_ragged_matches_value_path(impl):
    """Packed quantize on ragged K == pack(value-path quantize of the
    group-padded input), for both impls."""
    rng = np.random.default_rng(46)
    x = jnp.asarray(rng.normal(0, 4, (10, 70)), jnp.float32)
    xpad = jnp.pad(x, ((0, 0), (0, 26)))
    for name in MX_NAMES:
        p, s8 = ops.mx_quantize(x, name, impl=impl, packed=True)
        q, s = ops.mx_quantize(xpad, name, impl="xla")
        np.testing.assert_array_equal(np.asarray(p),
                                      np.asarray(ops.mx_pack(q, name)))
        np.testing.assert_array_equal(np.asarray(s8),
                                      np.asarray(F.e8m0_encode(s)))


def test_mx_pack_ragged_pads_to_alignment():
    """mx_pack itself accepts a K that is not pack-aligned (satellite:
    pad-and-mask instead of erroring)."""
    q = jnp.asarray([[1.0, -1.0, 0.5, 2.0, 1.5]], jnp.float32)  # K=5
    p = ops.mx_pack(q, "mxfp4e2m1")
    assert p.shape == (1, 3)                    # ceil(5/2) bytes
    back = ops.mx_unpack(p, "mxfp4e2m1", k=5)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))
