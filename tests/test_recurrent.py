"""Recurrent-engine correctness: the chunked GLA scan (shared by Mamba2/SSD
and mLSTM) against the naive step-by-step recurrence, plus decode-vs-prefill
consistency for the recurrent model families (the dense-family version of
this test lives in test_arch_smoke.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS
from repro.models import build_model
from repro.models.ssm import chunked_gla, gla_step

RNG = np.random.default_rng(11)


def _naive_gla(q, k, v, log_a):
    """Step-by-step reference: H_t = a_t H_{t-1} + k_t v_t^T; y_t = q_t H_t."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    hst = np.zeros((b, h, dk, dv), np.float64)
    ys = np.zeros((b, s, h, dv), np.float64)
    qf, kf, vf = (np.asarray(t, np.float64) for t in (q, k, v))
    af = np.exp(np.asarray(log_a, np.float64))
    for t in range(s):
        hst = af[:, t][..., None, None] * hst + np.einsum(
            "bhd,bhv->bhdv", kf[:, t], vf[:, t])
        ys[:, t] = np.einsum("bhd,bhdv->bhv", qf[:, t], hst)
    return ys, hst


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_chunked_gla_matches_naive_recurrence(chunk):
    b, s, h, dk, dv = 2, 32, 3, 5, 7
    q = jnp.asarray(RNG.normal(0, 1, (b, s, h, dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, h, dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, h, dv)), jnp.float32)
    la = jnp.asarray(-np.abs(RNG.normal(0, 0.5, (b, s, h))), jnp.float32)
    y, hT = chunked_gla(q, k, v, la, chunk=chunk)
    y_ref, h_ref = _naive_gla(q, k, v, la)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), h_ref, rtol=2e-4, atol=2e-4)


def test_gla_step_continues_chunked_state():
    """prefill (chunked) then decode (gla_step) == one long chunked run."""
    b, s, h, dk, dv = 1, 16, 2, 4, 4
    q = jnp.asarray(RNG.normal(0, 1, (b, s + 1, h, dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s + 1, h, dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s + 1, h, dv)), jnp.float32)
    la = jnp.asarray(-np.abs(RNG.normal(0, 0.3, (b, s + 1, h))), jnp.float32)
    y_full, h_full = chunked_gla(q, k, v, la, chunk=4)
    y_pre, h_pre = chunked_gla(q[:, :s], k[:, :s], v[:, :s], la[:, :s],
                               chunk=4)
    y_dec, h_dec = gla_step(q[:, s], k[:, s], v[:, s], la[:, s], h_pre)
    np.testing.assert_allclose(np.asarray(y_dec),
                               np.asarray(y_full[:, s]), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_dec), np.asarray(h_full),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]))
def test_property_gla_chunk_invariance(seed, chunk):
    """Invariant: the chunk size never changes the result."""
    rng = np.random.default_rng(seed)
    b, s, h, dk, dv = 1, 16, 2, 3, 3
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, h, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, h, dv)), jnp.float32)
    la = jnp.asarray(-np.abs(rng.normal(0, 0.5, (b, s, h))), jnp.float32)
    y1, h1 = chunked_gla(q, k, v, la, chunk=chunk)
    y2, h2 = chunked_gla(q, k, v, la, chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=5e-4,
                               atol=5e-4)


@pytest.mark.parametrize("arch", ["zamba2-7b", "xlstm-125m"])
def test_recurrent_decode_matches_prefill(arch):
    """Token-by-token decode (state caches) == teacher-forced forward."""
    cfg = dataclasses.replace(ARCHS[arch].reduced(), policy_name="bf16")
    model = build_model(cfg)
    rng = np.random.default_rng(5)
    params = model.init(jax.random.key(5))
    batch, seq = 2, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)))
    full_logits, _ = jax.jit(lambda p, t: model.apply(p, t))(params, tokens)
    cache = model.init_cache(batch, seq)
    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c))
    outs = []
    for i in range(seq):
        lg, cache = step(params, tokens[:, i], cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=4e-2, atol=4e-2)
