"""ExSdotp / ExVsum / Vsum — the paper's fused expanding dot-product unit.

Semantics (paper §III-B, Fig. 4): the unit computes

    ExSdotp_2w = a_w * b_w + c_w * d_w + e_2w          (eq. 1)
    ExVsum_2w  = a_w + c_w + e_2w                      (eq. 5, b=d=1)
    Vsum_2w    = a_2w + c_2w + e_2w                    (eq. 6, mults bypassed)

with a *single* normalization/rounding step. The hardware sorts the three
addends by magnitude and widens the internal datapath to
``2*p_dst + p_src + 5`` bits (plus sticky), which — together with the
exact-zero recovery rule — makes the result the correctly-rounded value of
the exact real-number sum. That is the specification implemented here:

* ``exsdotp_np`` — bit-exact oracle via exact dyadic-rational (bignum)
  arithmetic + one RNE rounding into the destination format.
* ``exfma_cascade_np`` — the discrete baseline (Fig. 3 left): two chained
  expanding FMAs, i.e. *two* roundings; used for the Table IV accuracy
  comparison and the area/perf comparisons.
* ``exsdotp`` (JAX) — jit-safe implementation using error-free TwoSum
  transformations; matches the oracle to <=1 ulp (ties in the compensation
  term), and is exact for all 8-bit source formats in practice.

In the *framework* (GEMM kernels, QLinear), the same principle appears as
"multiply narrow, accumulate wide, round once": fp32 VMEM accumulators with
a single downcast — strictly wider than the paper's 8->16 accumulation, so
the paper's accuracy claims are conservatively preserved (DESIGN.md §2).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .formats import MiniFloatFormat, get_format, quantize, quantize_np, EXPANDING_DST

__all__ = [
    "exsdotp_np", "exvsum_np", "vsum_np", "exfma_np", "exfma_cascade_np",
    "exsdotp_chain_np", "exfma_chain_np", "exsdotp_gemm_np",
    "exsdotp", "vsum", "two_sum",
]


# ---------------------------------------------------------------------------
# Exact dyadic arithmetic oracle (numpy / python bignum)
# ---------------------------------------------------------------------------

def _to_dyadic(x: float) -> Tuple[int, int]:
    """Exact (mantissa, exponent) with x == m * 2**k, for finite float64."""
    if x == 0.0:
        return 0, 0
    m, e = math.frexp(x)          # x = m * 2**e, 0.5 <= |m| < 1
    mi = int(m * (1 << 53))       # exact: float64 has 53 significant bits
    return mi, e - 53


def _round_dyadic(m: int, k: int, fmt: MiniFloatFormat) -> float:
    """RNE-round the exact value m * 2**k into ``fmt`` (returned as float64).

    This is the single rounding step at the end of the fused datapath.
    """
    if m == 0:
        return 0.0
    s = -1.0 if m < 0 else 1.0
    m = abs(m)
    e = k + m.bit_length() - 1                     # floor(log2 |value|)
    ulp_exp = max(e, fmt.min_exp) - fmt.man_bits   # spacing at this magnitude
    shift = ulp_exp - k
    if shift <= 0:
        q = m << (-shift)
    else:
        q = m >> shift
        rem = m & ((1 << shift) - 1)
        half = 1 << (shift - 1)
        if rem > half or (rem == half and (q & 1)):
            q += 1
    val = s * q * math.ldexp(1.0, ulp_exp)
    if abs(val) > fmt.max_normal:
        return s * (math.inf if fmt.inf_behavior == "ieee" else fmt.max_normal)
    return val


def _exact_3sum_round(terms, fmt: MiniFloatFormat) -> float:
    """Correctly-rounded sum of exactly-represented float64 terms."""
    if any(math.isnan(t) for t in terms):
        return math.nan
    infs = [t for t in terms if math.isinf(t)]
    if infs:
        if all(t > 0 for t in infs):
            return math.inf
        if all(t < 0 for t in infs):
            return -math.inf
        return math.nan
    dy = [_to_dyadic(t) for t in terms]
    kmin = min(k for _, k in dy)
    total = sum(m << (k - kmin) for m, k in dy)
    return _round_dyadic(total, kmin, fmt)


def _as_flat_f64(*arrays):
    arrs = [np.asarray(a, np.float64) for a in arrays]
    shape = np.broadcast_shapes(*[a.shape for a in arrs])
    return [np.broadcast_to(a, shape).ravel() for a in arrs], shape


def _two_sum_np(x, y):
    """Vectorized Knuth TwoSum: x + y == s + err, exactly (f64)."""
    s = x + y
    bv = s - x
    err = (x - (s - bv)) + (y - bv)
    return s, err


def _fused_3sum_rne_np(t1, t2, t3, fmt: MiniFloatFormat):
    """Vectorized correctly-rounded three-term sum of exact f64 terms.

    TwoSum cascade collapses t1+t2+t3 into w + e4 + e3 (exactly); the
    53-bit intermediate is then nudged to *round-to-odd* toward the
    residual, after which a single RNE into ``fmt`` is the correctly
    rounded result of the exact sum — valid whenever
    ``fmt.precision + 2 <= 53`` (every format here; fp32 dst = 26).

    Returns ``(out, fallback_mask)``; masked lanes (non-finite terms, or
    the total-cancellation corner where w == 0 with residual left) must
    be recomputed with the scalar dyadic-bignum path.
    """
    with np.errstate(all="ignore"):
        s, e1 = _two_sum_np(t1, t2)
        v, e2 = _two_sum_np(s, t3)      # x = v + e1 + e2, exactly
        r, e3 = _two_sum_np(e1, e2)     # x = v + r  + e3, exactly
        w, e4 = _two_sum_np(v, r)       # x = w + e4 + e3, exactly
        rho = e4 + e3                   # sign-exact residual (Hauser)
        bits = np.ascontiguousarray(w).view(np.uint64).reshape(w.shape)
        need_odd = (rho != 0) & ((bits & np.uint64(1)) == 0)
        w_odd = np.where(
            need_odd,
            np.nextafter(w, np.where(rho > 0, np.inf, -np.inf)), w)
        out = quantize_np(w_odd, fmt)
    fallback = (~np.isfinite(t1) | ~np.isfinite(t2) | ~np.isfinite(t3)
                | ((w == 0) & (rho != 0)))
    return out, fallback


def exsdotp_np(a, b, c, d, e, src_fmt, dst_fmt=None) -> np.ndarray:
    """Oracle: fused r = RNE_dst(a*b + c*d + e), inputs quantized to formats.

    Vectorized (TwoSum expansion + round-to-odd; see
    ``_fused_3sum_rne_np``) with a per-element fallback to the exact
    dyadic-bignum path on special values — fast enough to drive
    GEMM-sized accuracy tests (DESIGN.md §6).
    """
    src = get_format(src_fmt)
    dst = get_format(dst_fmt) if dst_fmt is not None else EXPANDING_DST[src.name]
    a, b, c, d = (quantize_np(x, src) for x in (a, b, c, d))
    (a, b, c, d, e), shape = _as_flat_f64(a, b, c, d, quantize_np(e, dst))
    with np.errstate(all="ignore"):
        # products of src-format values are exact in float64 (2*p_src <= 53)
        p1, p2 = a * b, c * d
    out, fallback = _fused_3sum_rne_np(p1, p2, e, dst)
    for i in np.nonzero(fallback)[0]:
        out[i] = _exact_3sum_round((p1[i], p2[i], e[i]), dst)
    return out.reshape(shape)


def exvsum_np(a, c, e, src_fmt, dst_fmt=None) -> np.ndarray:
    """Oracle ExVsum: b = d = 1 on the same datapath (paper eq. 5)."""
    src = get_format(src_fmt)
    return exsdotp_np(a, np.ones_like(np.asarray(a, np.float64)),
                      c, np.ones_like(np.asarray(c, np.float64)), e,
                      src, dst_fmt)


def vsum_np(a, c, e, fmt) -> np.ndarray:
    """Oracle Vsum: non-expanding three-term add (paper eq. 6)."""
    f = get_format(fmt)
    a, c, e = (quantize_np(x, f) for x in (a, c, e))
    (a, c, e), shape = _as_flat_f64(a, c, e)
    out = np.empty(a.shape, np.float64)
    for i in range(a.size):
        out[i] = _exact_3sum_round((a[i], c[i], e[i]), f)
    return out.reshape(shape)


def exfma_np(a, b, e, src_fmt, dst_fmt=None) -> np.ndarray:
    """Expanding FMA: RNE_dst(a*b + e) — one rounding (it *is* fused)."""
    src = get_format(src_fmt)
    dst = get_format(dst_fmt) if dst_fmt is not None else EXPANDING_DST[src.name]
    a, b = quantize_np(a, src), quantize_np(b, src)
    (a, b, e), shape = _as_flat_f64(a, b, quantize_np(e, dst))
    out = np.empty(a.shape, np.float64)
    for i in range(a.size):
        out[i] = _exact_3sum_round((a[i] * b[i], e[i], 0.0), dst)
    return out.reshape(shape)


def exfma_cascade_np(a, b, c, d, e, src_fmt, dst_fmt=None) -> np.ndarray:
    """Discrete baseline (Fig. 3, left): a*b + (c*d + e), TWO roundings.

    Not necessarily equal to the fused result — this is the unit the paper
    beats on both accuracy (Table IV) and area/critical path (Fig. 7a).
    """
    t = exfma_np(c, d, e, src_fmt, dst_fmt)
    return exfma_np(a, b, t, src_fmt, dst_fmt)


def exsdotp_chain_np(prods_a, prods_b, src_fmt, dst_fmt=None, init=0.0) -> np.ndarray:
    """Fig. 9 accumulation: chain ExSdotp over consecutive product pairs.

    acc_{i+1} = ExSdotp(a_{2i}, b_{2i}, a_{2i+1}, b_{2i+1}, acc_i);
    n odd is handled with a trailing ExFMA.
    """
    a = np.asarray(prods_a, np.float64).ravel()
    b = np.asarray(prods_b, np.float64).ravel()
    acc = np.float64(init)
    n = a.size
    for i in range(0, n - 1, 2):
        acc = exsdotp_np(a[i], b[i], a[i + 1], b[i + 1], acc, src_fmt, dst_fmt)[()]
    if n % 2:
        acc = exfma_np(a[-1], b[-1], acc, src_fmt, dst_fmt)[()]
    return np.float64(acc)


def exsdotp_gemm_np(a, b, src_fmt, acc_fmt="fp32", init=None) -> np.ndarray:
    """GEMM as a *vectorized* ExSdotp chain over K — the kernel's numerics.

    ``a[M, K]`` and ``b[K, N]`` are quantized into ``src_fmt``; the
    accumulator chains ExSdotp over consecutive K pairs with dst =
    ``acc_fmt`` (the Pallas kernel's fp32 VMEM accumulator), a trailing
    ExFMA handling odd K.  All (M, N) lanes advance together through the
    vectorized oracle, so a 128x128x128 GEMM checks in seconds rather
    than hours (DESIGN.md §6).  Returns the f64-held accumulator values
    (each exactly representable in ``acc_fmt``) — callers apply their
    own dequant scale + final rounding.
    """
    src = get_format(src_fmt)
    acc_f = get_format(acc_fmt)
    a = quantize_np(np.asarray(a, np.float64), src)
    b = quantize_np(np.asarray(b, np.float64), src)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    acc = np.zeros((m, n)) if init is None else \
        np.broadcast_to(np.asarray(init, np.float64), (m, n)).copy()
    for t in range(0, k - 1, 2):
        acc = exsdotp_np(a[:, t, None], b[None, t, :],
                         a[:, t + 1, None], b[None, t + 1, :],
                         acc, src, acc_f)
    if k % 2:
        acc = exfma_np(a[:, -1, None], b[None, -1, :], acc, src, acc_f)
    return acc


def exfma_chain_np(prods_a, prods_b, src_fmt, dst_fmt=None, init=0.0) -> np.ndarray:
    """Fig. 9 baseline: accumulate one expanding FMA at a time."""
    a = np.asarray(prods_a, np.float64).ravel()
    b = np.asarray(prods_b, np.float64).ravel()
    acc = np.float64(init)
    for i in range(a.size):
        acc = exfma_np(a[i], b[i], acc, src_fmt, dst_fmt)[()]
    return np.float64(acc)


# ---------------------------------------------------------------------------
# JAX implementations (jit/pjit/Pallas-safe)
# ---------------------------------------------------------------------------

def two_sum(x: jax.Array, y: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Knuth's error-free transformation: x + y == s + err exactly."""
    s = x + y
    bv = s - x
    err = (x - (s - bv)) + (y - bv)
    return s, err


def exsdotp(a, b, c, d, e, src_fmt, dst_fmt=None) -> jax.Array:
    """Fused expanding sum-of-dot-product, single rounding into dst.

    Inputs are quantized into ``src_fmt`` (accumulator into ``dst_fmt``).
    Products of any supported source format are exact in f32
    (2*p_src <= 24 bits for all of fp8/fp8alt/fp16/fp16alt); the three-term
    sum uses TwoSum compensation, then rounds once.
    """
    src = get_format(src_fmt)
    dst = get_format(dst_fmt) if dst_fmt is not None else EXPANDING_DST[src.name]
    assert 2 * src.precision <= 24, f"products of {src} not exact in f32"
    a, b, c, d = (quantize(x, src) for x in (a, b, c, d))
    e = quantize(e, dst)
    p1 = a * b
    p2 = c * d
    s1, e1 = two_sum(p1, p2)
    s2, e2 = two_sum(s1, e)
    return quantize(s2 + (e1 + e2), dst)


def vsum(a, c, e, fmt) -> jax.Array:
    """Non-expanding fused three-term addition (single rounding)."""
    f = get_format(fmt)
    a, c, e = quantize(a, f), quantize(c, f), quantize(e, f)
    s1, e1 = two_sum(a, c)
    s2, e2 = two_sum(s1, e)
    return quantize(s2 + (e1 + e2), f)
