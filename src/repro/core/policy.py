"""Mixed-precision policies — the CSR ``src_is_alt``/``dst_is_alt`` bits,
framework-scale.

The paper controls which minifloat format each kernel uses through two CSR
bits; here a ``Policy`` object threads the same decision through every
layer. The flagship policy is the paper's target workload, HFP8
(Sun et al. [7], cited in §I/§II-A): FP8alt (E4M3) forward, FP8 (E5M2)
backward, wide accumulation — exactly the format pairing the ExSdotp unit
exists to serve.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

__all__ = ["Policy", "HFP8", "FP8E4", "MXFP8", "MXFP6", "MXFP4",
           "BF16", "FP16", "FP32", "POLICIES", "get_policy"]


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str
    #: GEMM operand format on the forward pass (None = no quantization)
    fwd_dtype: Optional[jnp.dtype]
    #: GEMM operand format for gradients on the backward pass
    bwd_dtype: Optional[jnp.dtype]
    #: dtype activations/params are carried in between GEMMs
    compute_dtype: jnp.dtype
    #: master weights / optimizer accumulation dtype
    param_dtype: jnp.dtype
    #: block size for blockwise scaling; 0 = per-tensor scaling
    block_scale: int = 0
    #: quantization headroom for block scales: the quantized amax lands
    #: at ``block_margin * max_normal`` (< 1 reserves range)
    block_margin: float = 1.0
    #: round block scales up to powers of two (MX-style shared
    #: exponents); pow2 rescaling is exact, so dequant adds no rounding
    block_pow2: bool = True
    #: MX format names (DESIGN.md §8/§10) for the forward / backward
    #: GEMM operands; non-empty routes every QLinear through the packed
    #: MX pipeline (``ops.mx_quantize(packed=True)`` →
    #: ``ops.mx_gemm_packed``: groups of 32 along K, E8M0 shared scales,
    #: payloads packed to ``width/8`` bytes per element) instead of the
    #: per-tensor or block-scaled paths.  ``mx_bwd`` defaults to
    #: ``mx_fwd`` when only the forward format is given.
    mx_fwd: str = ""
    mx_bwd: str = ""
    #: wgrad operand formats (activation side / gradient side).  Sub-byte
    #: training recipes keep the weight-gradient GEMM in wider "master"
    #: formats (Graphcore/IBM FP8 master wgrad): ``mxfp6``/``mxfp4`` set
    #: these to the MXFP8 pair while fwd/dgrad run 6/4-bit.  Empty
    #: defaults to ``mx_fwd`` / ``mx_bwd`` (the mxfp8 behavior).
    mx_wgrad_act: str = ""
    mx_wgrad_grad: str = ""
    #: MX format for the attention KV sweep (DESIGN.md §11): k/v stream
    #: into the flash kernel as packed payloads with E8M0 group scales
    #: over the head dimension, decoded in-register next to the f32
    #: online-softmax accumulator.  Forward-path tensors tolerate the
    #: narrow element formats (Noune et al. 2206.02915), so each MX
    #: policy uses its *forward* element format here; empty defaults to
    #: ``mx_fwd``.  q and the (m, l, acc) state stay in the carrier /
    #: f32 — only the streamed KV operands narrow.
    mx_attn: str = ""
    #: MX format for the cross-replica DP gradient wire (DESIGN.md §13):
    #: ``optim.grad_compress.compressed_psum_mean`` ships each gradient
    #: leaf as packed codec payloads + E8M0 group grids (groups of 32
    #: over the flattened leaf, leaves padded to whole groups) instead
    #: of the per-leaf single-scale FP8 path, with per-leaf error
    #: feedback absorbing the group-quantization residual.  Gradients
    #: are the range-hungry side, so each policy uses its *backward*
    #: element format here; empty keeps the legacy per-leaf FP8-E5M2
    #: wire.
    mx_dp_grad: str = ""
    #: MX element format for the *serving* KV cache (DESIGN.md §12):
    #: decode caches store packed codec payloads + E8M0 scale codes in
    #: fixed-size page slots instead of carrier-precision tensors, and
    #: the decode attention kernel dequantizes groups in-register.
    #: Serving is pure-forward — the best case for the narrow formats —
    #: so each MX policy uses its forward element format here.  Empty
    #: keeps the bf16 carrier cache (also the fallback for head dims
    #: that are not a whole number of groups).
    mx_kv_cache: str = ""
    #: loss-scaling needed? (fp16/fp8-e5m2 gradients have narrow range)
    loss_scaling: bool = False

    @property
    def quantized(self) -> bool:
        return self.fwd_dtype is not None or bool(self.mx_fwd)

    @property
    def block_cfg(self):
        """``BlockScaleConfig`` for this policy, or None for per-tensor.

        With a config, every QLinear GEMM runs the fused block-scaled
        path (quantize-in-kernel, per-block dequant — DESIGN.md §3).
        """
        from .scaling import BlockScaleConfig
        return BlockScaleConfig.from_policy(self)

    @property
    def mx(self) -> bool:
        return bool(self.mx_fwd)

    @property
    def mx_bwd_name(self) -> str:
        return self.mx_bwd or self.mx_fwd

    @property
    def mx_wgrad_act_name(self) -> str:
        return self.mx_wgrad_act or self.mx_fwd

    @property
    def mx_wgrad_grad_name(self) -> str:
        return self.mx_wgrad_grad or self.mx_bwd_name

    @property
    def mx_attn_name(self) -> str:
        return self.mx_attn or self.mx_fwd

    @property
    def mx_kv_cache_name(self) -> str:
        return self.mx_kv_cache or self.mx_attn_name


# The paper's training recipe: E4M3 forward (more precision), E5M2 backward
# (more range — gradients are long-tailed), fp32 accumulate, bf16 carrier.
HFP8 = Policy("hfp8", jnp.float8_e4m3, jnp.float8_e5m2,
              jnp.bfloat16, jnp.float32, loss_scaling=True)
#: E4M3 both directions (inference-style / forward-dominant)
FP8E4 = Policy("fp8e4", jnp.float8_e4m3, jnp.float8_e4m3,
               jnp.bfloat16, jnp.float32)
#: HFP8 with 128x128 block scaling (beyond-paper; DeepSeek-V3-style)
HFP8_BLOCK = Policy("hfp8_block", jnp.float8_e4m3, jnp.float8_e5m2,
                    jnp.bfloat16, jnp.float32, block_scale=128,
                    loss_scaling=True)
#: HFP8 pairing at MX granularity (DESIGN.md §8): E4M3 elements forward,
#: E5M2 backward, each 32-element K-group under its own E8M0 shared
#: exponent — fwd/dgrad/wgrad all run ``ops.mx_gemm``.
MXFP8 = Policy("mxfp8", jnp.float8_e4m3, jnp.float8_e5m2,
               jnp.bfloat16, jnp.float32,
               mx_fwd="mxfp8e4m3", mx_bwd="mxfp8e5m2",
               mx_attn="mxfp8e4m3", mx_kv_cache="mxfp8e4m3",
               mx_dp_grad="mxfp8e5m2",
               loss_scaling=True)
#: Sub-byte MX training policies (DESIGN.md §10): payloads stay packed
#: (0.75 / 0.5 B per element) from the quantize kernel through the GEMM
#: and across the explicit TP wire.  mxfp6 pairs E2M3 forward (more
#: precision) with E3M2 backward (more range — the same asymmetry as
#: HFP8, one format class down); mxfp4 runs E2M1 forward with FP8-E5M2
#: gradients (4-bit grads don't train).  Both keep the weight-gradient
#: GEMM in the MXFP8 pair — the "FP8 master wgrad" recipe.
MXFP6 = Policy("mxfp6", jnp.float8_e4m3, jnp.float8_e5m2,
               jnp.bfloat16, jnp.float32,
               mx_fwd="mxfp6e2m3", mx_bwd="mxfp6e3m2",
               mx_wgrad_act="mxfp8e4m3", mx_wgrad_grad="mxfp8e5m2",
               mx_attn="mxfp6e2m3", mx_kv_cache="mxfp6e2m3",
               mx_dp_grad="mxfp6e3m2",
               loss_scaling=True)
MXFP4 = Policy("mxfp4", jnp.float8_e4m3, jnp.float8_e5m2,
               jnp.bfloat16, jnp.float32,
               mx_fwd="mxfp4e2m1", mx_bwd="mxfp8e5m2",
               mx_wgrad_act="mxfp8e4m3", mx_wgrad_grad="mxfp8e5m2",
               mx_attn="mxfp4e2m1", mx_kv_cache="mxfp4e2m1",
               mx_dp_grad="mxfp4e2m1",
               loss_scaling=True)
BF16 = Policy("bf16", None, None, jnp.bfloat16, jnp.float32)
FP16 = Policy("fp16", None, None, jnp.float16, jnp.float32,
              loss_scaling=True)
FP32 = Policy("fp32", None, None, jnp.float32, jnp.float32)

POLICIES = {p.name: p for p in (HFP8, FP8E4, HFP8_BLOCK, MXFP8, MXFP6,
                                MXFP4, BF16, FP16, FP32)}


def get_policy(name) -> Policy:
    if isinstance(name, Policy):
        return name
    return POLICIES[str(name).lower()]
