"""QLinear — every GEMM in the framework routes through the paper's
expanding-dot-product primitive.

Forward (HFP8): x, W are quantized per-tensor (or per-block) into FP8alt
(E4M3), multiplied narrow, accumulated fp32, rounded once into the carrier
dtype — a GEMM-sized ExSdotp chain.  Backward: gradients are quantized into
FP8 (E5M2, wider range) for both dgrad and wgrad GEMMs, again with fp32
accumulation.  This is Sun et al.'s HFP8 recipe, the workload the
MiniFloat-NN ISA was designed for, expressed as a ``jax.custom_vjp``.

First/last layers (embedding, logits) conventionally stay un-quantized;
models decide via config flags.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.ops import resolve_impl
from .policy import Policy, get_policy

__all__ = ["qlinear", "linear"]


def _gemm(a, b, scale, out_dtype, impl):
    return ops.exsdotp_gemm(a, b, scale, out_dtype=out_dtype, impl=impl)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _qlinear_nd(x, w, policy: Policy, impl: str):
    y, _ = _qlinear_nd_fwd(x, w, policy, impl)
    return y


def _qlinear_nd_fwd(x, w, policy: Policy, impl: str):
    """x [..., K] @ w [K, N] — native rank: no reshape, so sharded leading
    dims (batch/sequence-parallel) survive into the GEMM instead of being
    all-gathered by a flatten (§Perf iteration D1)."""
    if policy.mx:
        # fused MX path (DESIGN.md §8): per-(row × group-of-32-along-K)
        # E8M0 shared exponents, quantize-in-kernel; like the block path,
        # residuals are the high-precision operands (bwd re-quantizes
        # fused, in the backward formats).  Native rank: MX scales are
        # per-row, so leading dims stay batch dims.
        y = ops.mx_gemm(x, w, mx_a=policy.mx_fwd,
                        out_dtype=policy.compute_dtype, impl=impl)
        return y, (x, w)
    cfg = policy.block_cfg
    if cfg is not None:
        # fused block-scaled path (DESIGN.md §3): per-(row-tile × K-tile)
        # scales, cast in VMEM inside the GEMM — no separate quantize pass
        # over HBM, and no quantized residuals (bwd re-quantizes fused too).
        # Native rank: row tiles live on the unflattened token axes
        # (per-(batch, seq-tile) granularity), so sequence-sharded leading
        # dims survive into the GEMM like the per-tensor branch (D1) —
        # no flatten-induced GSPMD reshard.
        y = ops.blockscale_gemm(
            x, w, q_dtype_a=policy.fwd_dtype,
            cfg=cfg, out_dtype=policy.compute_dtype, impl=impl)
        return y, (x, w)
    xq, sx = ops.quantize_tensor(x, policy.fwd_dtype)
    wq, sw = ops.quantize_tensor(w, policy.fwd_dtype)
    if resolve_impl(impl) == "xla":
        acc = jnp.dot(xq.astype(jnp.float32), wq.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        y = (acc * (sx * sw)).astype(policy.compute_dtype)
    else:
        lead = x.shape[:-1]
        y = _gemm(xq.reshape(-1, x.shape[-1]), wq, sx * sw,
                  policy.compute_dtype, impl).reshape(*lead, w.shape[-1])
    return y, (xq, sx, wq, sw)


def _qlinear_nd_bwd(policy: Policy, impl: str, res, g):
    if policy.mx:
        x, w = res
        cd = policy.compute_dtype
        # dgrad: E5M2-element grads × E4M3-element weights, groups of 32
        # along the contracted N axis; wgrad: E4M3 acts × E5M2 grads,
        # groups along the contracted token axis (dW sums over all
        # tokens, so the flatten is by construction).
        dx = ops.mx_gemm(g, w.T, mx_a=policy.mx_bwd_name,
                         mx_b=policy.mx_fwd, out_dtype=cd, impl=impl)
        g2 = g.reshape(-1, g.shape[-1])
        x2 = x.reshape(-1, x.shape[-1])
        dw = ops.mx_gemm(x2.T, g2, mx_a=policy.mx_fwd,
                         mx_b=policy.mx_bwd_name, out_dtype=cd, impl=impl)
        return dx, dw
    cfg = policy.block_cfg
    if cfg is not None:
        x, w = res
        cd = policy.compute_dtype
        # dgrad: E5M2 grads × E4M3 weights, native rank (sequence shards
        # survive); wgrad: E4M3 acts × E5M2 grads — the token contraction
        # flattens by construction (dW sums over all tokens anyway).
        dx = ops.blockscale_gemm(
            g, w.T, q_dtype_a=policy.bwd_dtype, q_dtype_b=policy.fwd_dtype,
            cfg=cfg, out_dtype=cd, impl=impl)
        g2 = g.reshape(-1, g.shape[-1])
        x2 = x.reshape(-1, x.shape[-1])
        dw = ops.blockscale_gemm(
            x2.T, g2, q_dtype_a=policy.fwd_dtype, q_dtype_b=policy.bwd_dtype,
            cfg=cfg, out_dtype=cd, impl=impl)
        return dx, dw
    xq, sx, wq, sw = res
    cd = policy.compute_dtype  # x and w were cast to this before the vjp
    gq, sg = ops.quantize_tensor(g, policy.bwd_dtype)
    nbatch = xq.ndim - 1
    if resolve_impl(impl) == "xla":
        # dgrad: dx[..., K] = g[..., N] @ W^T
        dx = (jnp.dot(gq.astype(jnp.float32), wq.astype(jnp.float32).T,
                      preferred_element_type=jnp.float32)
              * (sg * sw)).astype(cd)
        # wgrad: dW[K, N] = sum_... x[..., K] g[..., N]
        dw = (jnp.tensordot(xq.astype(jnp.float32), gq.astype(jnp.float32),
                            axes=(list(range(nbatch)), list(range(nbatch))))
              * (sx * sg)).astype(cd)
        return dx, dw
    k = xq.shape[-1]
    n = gq.shape[-1]
    g2 = gq.reshape(-1, n)
    x2 = xq.reshape(-1, k)
    dx = _gemm(g2, wq.T, sg * sw, cd, impl).reshape(xq.shape)
    dw = _gemm(x2.T, g2, sx * sg, cd, impl)
    return dx, dw


_qlinear_nd.defvjp(_qlinear_nd_fwd, _qlinear_nd_bwd)


def qlinear(x: jax.Array, w: jax.Array, policy, *, impl: str = "auto") -> jax.Array:
    """y[..., N] = x[..., K] @ w[K, N] under the mixed-precision policy."""
    policy = get_policy(policy)
    if not policy.quantized:
        cd = policy.compute_dtype
        return jnp.dot(x.astype(cd), w.astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
    return _qlinear_nd(x.astype(policy.compute_dtype),
                       w.astype(policy.compute_dtype), policy, impl)


def linear(x: jax.Array, w: jax.Array, b=None, *, policy, impl: str = "auto",
           quantized: bool = True) -> jax.Array:
    """Linear layer with optional bias; ``quantized=False`` opts a layer out
    (embedding/logits heads, norms' affine params, routers)."""
    policy = get_policy(policy)
    if quantized and policy.quantized:
        y = qlinear(x, w, policy, impl=impl)
    else:
        cd = policy.compute_dtype
        y = jnp.dot(x.astype(cd), w.astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y
