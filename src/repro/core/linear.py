"""QLinear — every GEMM in the framework routes through the paper's
expanding-dot-product primitive.

Forward (HFP8): x, W are quantized per-tensor (or per-block) into FP8alt
(E4M3), multiplied narrow, accumulated fp32, rounded once into the carrier
dtype — a GEMM-sized ExSdotp chain.  Backward: gradients are quantized into
FP8 (E5M2, wider range) for both dgrad and wgrad GEMMs, again with fp32
accumulation.  This is Sun et al.'s HFP8 recipe, the workload the
MiniFloat-NN ISA was designed for, expressed as a ``jax.custom_vjp``.

First/last layers (embedding, logits) conventionally stay un-quantized;
models decide via config flags.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.ops import resolve_impl
from .policy import Policy, get_policy

__all__ = ["qlinear", "linear"]


def _gemm(a, b, scale, out_dtype, impl):
    return ops.exsdotp_gemm(a, b, scale, out_dtype=out_dtype, impl=impl)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _qlinear_nd(x, w, policy: Policy, impl: str):
    y, _ = _qlinear_nd_fwd(x, w, policy, impl)
    return y


def _qlinear_nd_fwd(x, w, policy: Policy, impl: str):
    """x [..., K] @ w [K, N] — native rank: no reshape, so sharded leading
    dims (batch/sequence-parallel) survive into the GEMM instead of being
    all-gathered by a flatten (§Perf iteration D1)."""
    if policy.mx:
        # packed MX pipeline (DESIGN.md §10): quantize kernels emit the
        # packed uint8 payloads + E8M0 byte grids directly, the GEMM
        # consumes packed refs and decodes in-register — the operands
        # exist in HBM only at width/8 (+1/32) bytes per element.  The
        # activation residual is that same packed payload (0.53 B/elem
        # for FP4 vs 2 B bf16), re-grouped along the token axis in bwd
        # for wgrad.  Native rank: MX scales are per-row, so leading
        # dims stay batch dims.
        mxf = policy.mx_fwd
        xp, sx8 = ops.mx_quantize(x, mxf, impl=impl, packed=True)
        wp, sw8 = ops.mx_quantize(w.T, mxf, impl=impl, packed=True)
        y = ops.mx_gemm_packed(xp, sx8, wp, sw8, mx_a=mxf,
                               out_dtype=policy.compute_dtype, impl=impl)
        return y, (xp, sx8, w)
    cfg = policy.block_cfg
    if cfg is not None:
        # fused block-scaled path (DESIGN.md §3): per-(row-tile × K-tile)
        # scales, cast in VMEM inside the GEMM — no separate quantize pass
        # over HBM, and no quantized residuals (bwd re-quantizes fused too).
        # Native rank: row tiles live on the unflattened token axes
        # (per-(batch, seq-tile) granularity), so sequence-sharded leading
        # dims survive into the GEMM like the per-tensor branch (D1) —
        # no flatten-induced GSPMD reshard.
        y = ops.blockscale_gemm(
            x, w, q_dtype_a=policy.fwd_dtype,
            cfg=cfg, out_dtype=policy.compute_dtype, impl=impl)
        return y, (x, w)
    xq, sx = ops.quantize_tensor(x, policy.fwd_dtype)
    wq, sw = ops.quantize_tensor(w, policy.fwd_dtype)
    if resolve_impl(impl) == "xla":
        acc = jnp.dot(xq.astype(jnp.float32), wq.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        y = (acc * (sx * sw)).astype(policy.compute_dtype)
    else:
        lead = x.shape[:-1]
        y = _gemm(xq.reshape(-1, x.shape[-1]), wq, sx * sw,
                  policy.compute_dtype, impl).reshape(*lead, w.shape[-1])
    return y, (xq, sx, wq, sw)


def _qlinear_nd_bwd(policy: Policy, impl: str, res, g):
    if policy.mx:
        xp, sx8, w = res
        cd = policy.compute_dtype
        mxf, mxb = policy.mx_fwd, policy.mx_bwd_name
        mxwa = policy.mx_wgrad_act_name
        mxwg = policy.mx_wgrad_grad_name
        k, n = w.shape
        # dgrad: bwd-format grads × fwd-format weights, groups of 32
        # along the contracted N axis on both packed operands.
        gp, sg8 = ops.mx_quantize(g, mxb, impl=impl, packed=True)
        wnp, swn8 = ops.mx_quantize(w, mxf, impl=impl, packed=True)
        dx = ops.mx_gemm_packed(gp, sg8, wnp, swn8, mx_a=mxb, mx_b=mxf,
                                out_dtype=cd, impl=impl)
        # wgrad (possibly in wider "master" formats — mx_wgrad_*): both
        # operands re-group along the contracted token axis (dW sums
        # over all tokens, so the flatten is by construction).  x comes
        # from its packed fwd payload — the one fwd rounding the narrow
        # residual implies, exactly like the per-tensor path's fp8
        # residuals; the raw cotangent takes no extra rounding.
        xf = ops.mx_dequantize_packed(xp, sx8, mxf, k=k)
        x2 = xf.reshape(-1, k)
        g2 = g.astype(jnp.float32).reshape(-1, n)
        xtp, sxt8 = ops.mx_quantize(x2.T, mxwa, impl=impl, packed=True)
        gtp, sgt8 = ops.mx_quantize(g2.T, mxwg, impl=impl, packed=True)
        dw = ops.mx_gemm_packed(xtp, sxt8, gtp, sgt8, mx_a=mxwa,
                                mx_b=mxwg, out_dtype=cd, impl=impl)
        return dx, dw
    cfg = policy.block_cfg
    if cfg is not None:
        x, w = res
        cd = policy.compute_dtype
        # dgrad: E5M2 grads × E4M3 weights, native rank (sequence shards
        # survive); wgrad: E4M3 acts × E5M2 grads — the token contraction
        # flattens by construction (dW sums over all tokens anyway).
        dx = ops.blockscale_gemm(
            g, w.T, q_dtype_a=policy.bwd_dtype, q_dtype_b=policy.fwd_dtype,
            cfg=cfg, out_dtype=cd, impl=impl)
        g2 = g.reshape(-1, g.shape[-1])
        x2 = x.reshape(-1, x.shape[-1])
        dw = ops.blockscale_gemm(
            x2.T, g2, q_dtype_a=policy.fwd_dtype, q_dtype_b=policy.bwd_dtype,
            cfg=cfg, out_dtype=cd, impl=impl)
        return dx, dw
    xq, sx, wq, sw = res
    cd = policy.compute_dtype  # x and w were cast to this before the vjp
    gq, sg = ops.quantize_tensor(g, policy.bwd_dtype)
    nbatch = xq.ndim - 1
    if resolve_impl(impl) == "xla":
        # dgrad: dx[..., K] = g[..., N] @ W^T
        dx = (jnp.dot(gq.astype(jnp.float32), wq.astype(jnp.float32).T,
                      preferred_element_type=jnp.float32)
              * (sg * sw)).astype(cd)
        # wgrad: dW[K, N] = sum_... x[..., K] g[..., N]
        dw = (jnp.tensordot(xq.astype(jnp.float32), gq.astype(jnp.float32),
                            axes=(list(range(nbatch)), list(range(nbatch))))
              * (sx * sg)).astype(cd)
        return dx, dw
    k = xq.shape[-1]
    n = gq.shape[-1]
    g2 = gq.reshape(-1, n)
    x2 = xq.reshape(-1, k)
    dx = _gemm(g2, wq.T, sg * sw, cd, impl).reshape(xq.shape)
    dw = _gemm(x2.T, g2, sx * sg, cd, impl)
    return dx, dw


_qlinear_nd.defvjp(_qlinear_nd_fwd, _qlinear_nd_bwd)


def qlinear(x: jax.Array, w: jax.Array, policy, *, impl: str = "auto") -> jax.Array:
    """y[..., N] = x[..., K] @ w[K, N] under the mixed-precision policy."""
    policy = get_policy(policy)
    if not policy.quantized:
        cd = policy.compute_dtype
        return jnp.dot(x.astype(cd), w.astype(cd),
                       preferred_element_type=jnp.float32).astype(cd)
    return _qlinear_nd(x.astype(policy.compute_dtype),
                       w.astype(policy.compute_dtype), policy, impl)


def linear(x: jax.Array, w: jax.Array, b=None, *, policy, impl: str = "auto",
           quantized: bool = True) -> jax.Array:
    """Linear layer with optional bias; ``quantized=False`` opts a layer out
    (embedding/logits heads, norms' affine params, routers)."""
    policy = get_policy(policy)
    if quantized and policy.quantized:
        y = qlinear(x, w, policy, impl=impl)
    else:
        cd = policy.compute_dtype
        y = jnp.dot(x.astype(cd), w.astype(cd),
                    preferred_element_type=jnp.float32).astype(cd)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y
