"""MiniFloat-NN format system (paper §III-A, Fig. 1).

Parameterized floating-point formats a la FPnew: any (exp_bits, man_bits)
pair defines a format; the paper's six formats are predefined. Two
implementations are provided and cross-tested:

  * a bit-exact *value-space* quantizer in pure JAX (`quantize`) — RNE,
    IEEE subnormals, overflow-to-inf — usable inside jit/pjit/Pallas;
  * exact bit-pattern `encode`/`decode` (numpy + JAX) for storage tests
    and for the integer-datapath ExSdotp oracle.

Native `ml_dtypes` counterparts (used on the performance path, where XLA/TPU
have hardware casts) are attached where they exist; the emulation layer is
authoritative for paper semantics.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

__all__ = [
    "MiniFloatFormat",
    "FP8", "FP8ALT", "FP16", "FP16ALT", "FP32", "FP64",
    "FORMATS", "get_format", "quantize", "quantize_np",
    "encode_np", "decode_np",
]


@dataclasses.dataclass(frozen=True)
class MiniFloatFormat:
    """An IEEE-754-style binary format with parametric field widths."""

    name: str
    exp_bits: int
    man_bits: int
    #: 'ieee'  -> overflow rounds to +-inf (paper semantics)
    #: 'saturate' -> overflow clamps to +-max_normal ("fn"-style, TPU casts)
    inf_behavior: str = "ieee"

    # ---- derived quantities ----------------------------------------
    @property
    def width(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def max_exp(self) -> int:  # unbiased exponent of largest normal
        return (1 << self.exp_bits) - 2 - self.bias

    @property
    def min_exp(self) -> int:  # unbiased exponent of smallest normal
        return 1 - self.bias

    @property
    def precision(self) -> int:  # p = man_bits + 1 (hidden one); paper's p_src/p_dst
        return self.man_bits + 1

    @property
    def max_normal(self) -> float:
        return float(2.0 ** self.max_exp * (2.0 - 2.0 ** (-self.man_bits)))

    @property
    def min_normal(self) -> float:
        return float(2.0 ** self.min_exp)

    @property
    def min_subnormal(self) -> float:
        return float(2.0 ** (self.min_exp - self.man_bits))

    @property
    def ml_dtype(self) -> Optional[np.dtype]:
        """Native ml_dtypes counterpart, if one exists (exact match)."""
        key = (self.exp_bits, self.man_bits)
        table = {
            (5, 2): np.dtype(ml_dtypes.float8_e5m2),
            (4, 3): np.dtype(ml_dtypes.float8_e4m3),
            (5, 10): np.dtype(np.float16),
            (8, 7): np.dtype(ml_dtypes.bfloat16),
            (8, 23): np.dtype(np.float32),
            (11, 52): np.dtype(np.float64),
        }
        return table.get(key)

    @property
    def storage_dtype(self):
        """jnp dtype used to *store* tensors in this format on the perf path.

        For formats with no native dtype we store uint bit patterns.
        """
        md = self.ml_dtype
        if md is not None:
            return md
        return np.dtype(f"uint{max(8, 1 << (self.width - 1).bit_length())}")

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.name}(E{self.exp_bits}M{self.man_bits})"


# The paper's formats (Fig. 1 / §III-A). FP16ALT keeps bfloat16 widths but
# full IEEE rounding + subnormals, which ml_dtypes.bfloat16 implements.
FP8 = MiniFloatFormat("fp8", 5, 2)
FP8ALT = MiniFloatFormat("fp8alt", 4, 3)
FP16 = MiniFloatFormat("fp16", 5, 10)
FP16ALT = MiniFloatFormat("fp16alt", 8, 7)
FP32 = MiniFloatFormat("fp32", 8, 23)
FP64 = MiniFloatFormat("fp64", 11, 52)

FORMATS = {f.name: f for f in (FP8, FP8ALT, FP16, FP16ALT, FP32, FP64)}

#: ExSdotp source->destination pairing (paper Table I): expanding ops double
#: the width. 8-bit formats expand into FP16/FP16alt; 16-bit into FP32.
EXPANDING_DST = {
    "fp8": FP16, "fp8alt": FP16,
    "fp16": FP32, "fp16alt": FP32,
}


def get_format(name) -> MiniFloatFormat:
    if isinstance(name, MiniFloatFormat):
        return name
    return FORMATS[str(name).lower()]


# ---------------------------------------------------------------------------
# Value-space quantization (JAX, bit-exact, jit-safe)
# ---------------------------------------------------------------------------

def _exact_pow2(k: jax.Array) -> jax.Array:
    """2**k as f32, exact, for integer k in [-149, 127] (incl. subnormals).

    jnp.exp2 is an approximation on some backends (CPU XLA returns
    8192.004 for exp2(13)!), so powers of two are built from raw bits.
    """
    k = k.astype(jnp.int32)
    kn = jnp.clip(k, -126, 127)
    bits_norm = ((kn + 127) << 23).astype(jnp.uint32)
    val_norm = jax.lax.bitcast_convert_type(bits_norm, jnp.float32)
    shift = jnp.clip(k + 149, 0, 22).astype(jnp.uint32)
    val_sub = jax.lax.bitcast_convert_type(jnp.uint32(1) << shift, jnp.float32)
    return jnp.where(k < -126, val_sub, val_norm)


def _quantize_f32(x: jax.Array, fmt: MiniFloatFormat) -> jax.Array:
    """Round f32 values to the nearest representable value of ``fmt`` (RNE).

    Pure value-space arithmetic on exact powers of two, so every step is
    exact in f32 and the result is bit-identical to a hardware cast.
    """
    x = x.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    biased = ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32)
    e = biased - 127  # floor(log2|x|) for normal f32; -127 for f32 subnormals
    # quantization step: ulp at e, clamped at the subnormal plateau
    step_exp = jnp.maximum(e, fmt.min_exp) - fmt.man_bits
    # Scale by 2**(-step_exp), round, scale back. step_exp can reach -133
    # (fp16alt subnormals), beyond f32 exponent range, so split into two
    # exact power-of-two factors.
    half_a = step_exp // 2
    half_b = step_exp - half_a
    q = jnp.round(x * _exact_pow2(-half_a) * _exact_pow2(-half_b))
    q = q * _exact_pow2(half_a) * _exact_pow2(half_b)
    if fmt.min_exp - fmt.man_bits < -126:
        # fmt has representable values inside the f32-subnormal range
        # (fp16alt: down to 2^-133). XLA CPU runs with DAZ/FTZ, so those
        # must be produced via integer bit manipulation, not arithmetic.
        # Inputs with biased exponent 0 are exactly the affected set
        # (fp16alt.min_normal == f32 min normal).
        sub_step = fmt.min_exp - fmt.man_bits          # e.g. -133
        man = (bits & jnp.uint32(0x7FFFFF)).astype(jnp.float32)  # x = man*2^-149
        qi = jnp.round(man * _exact_pow2(jnp.full(x.shape, -149 - sub_step)))
        deep_bits = (qi.astype(jnp.uint32) << (149 + sub_step)) | (bits & jnp.uint32(0x80000000))
        deep = jax.lax.bitcast_convert_type(deep_bits, jnp.float32)
        q = jnp.where(biased == 0, deep, q)
    # overflow: beyond max_normal rounds to inf (ieee) or clamps (saturate)
    max_normal = jnp.float32(fmt.max_normal)
    if fmt.inf_behavior == "ieee":
        over = jnp.where(jnp.isinf(x), x, jnp.sign(x) * jnp.inf)
    else:
        over = jnp.where(jnp.isinf(x), x, jnp.sign(x) * max_normal)
    q = jnp.where(jnp.abs(q) > max_normal, over.astype(jnp.float32), q)
    # NaN propagates through the arithmetic already; +-0 preserved by round.
    return q


def quantize(x: jax.Array, fmt) -> jax.Array:
    """Quantize to ``fmt``'s representable set; returns float32 values."""
    fmt = get_format(fmt)
    if fmt.name == "fp32":
        return jnp.asarray(x, jnp.float32)
    if fmt.name == "fp64":
        return jnp.asarray(x, jnp.float32)  # f32 value already exact in f64
    return _quantize_f32(jnp.asarray(x), fmt)


# ---------------------------------------------------------------------------
# numpy mirror (oracle; float64 internal so it also serves 16/32-bit formats)
# ---------------------------------------------------------------------------

def quantize_np(x: np.ndarray, fmt) -> np.ndarray:
    fmt = get_format(fmt)
    x = np.asarray(x, np.float64)
    if fmt.name == "fp64":
        return x
    with np.errstate(all="ignore"):
        m, e = np.frexp(x)  # x = m * 2^e, 0.5<=|m|<1  => floor(log2|x|) = e-1
        e = e - 1
        step_exp = np.maximum(e, fmt.min_exp) - fmt.man_bits
        step = np.ldexp(1.0, step_exp.astype(np.int64))
        # np.round is round-half-even
        q = np.round(x / np.where(step == 0, 1.0, step)) * step
        if fmt.inf_behavior == "ieee":
            over = np.where(np.isinf(x), x, np.sign(x) * np.inf)
        else:
            over = np.where(np.isinf(x), x, np.sign(x) * fmt.max_normal)
        q = np.where(np.abs(q) > fmt.max_normal, over, q)
        q = np.where(np.isnan(x), np.nan, q)
    return q


# ---------------------------------------------------------------------------
# Bit-pattern encode/decode (numpy; exact). Used by the ExSdotp oracle and
# storage round-trip tests for formats without a native dtype.
# ---------------------------------------------------------------------------

def encode_np(x: np.ndarray, fmt) -> np.ndarray:
    """Encode (already representable or arbitrary) values to fmt bit patterns."""
    fmt = get_format(fmt)
    q = quantize_np(np.asarray(x, np.float64), fmt)
    sign = (np.signbit(q)).astype(np.uint64)
    out = np.zeros(q.shape, np.uint64)
    aq = np.abs(q)
    nan = np.isnan(q)
    inf = np.isinf(q)
    sub = (aq < fmt.min_normal) & ~nan  # includes zero
    with np.errstate(all="ignore"):
        m, e = np.frexp(aq)
        e = e - 1
        # normals
        man_norm = np.rint((m * 2.0 - 1.0) * (1 << fmt.man_bits)).astype(np.uint64)
        exp_norm = (e + fmt.bias).astype(np.int64)
        # subnormals (and zero): value = man * 2^(min_exp - man_bits)
        man_sub = np.rint(aq / fmt.min_subnormal).astype(np.uint64)
    exp_field = np.where(sub, 0, np.clip(exp_norm, 0, (1 << fmt.exp_bits) - 1)).astype(np.uint64)
    man_field = np.where(sub, man_sub, man_norm).astype(np.uint64)
    exp_field = np.where(inf | nan, (1 << fmt.exp_bits) - 1, exp_field)
    man_field = np.where(inf, 0, man_field)
    man_field = np.where(nan, 1 << (fmt.man_bits - 1), man_field)  # quiet NaN
    out = (sign << (fmt.exp_bits + fmt.man_bits)) | (exp_field << fmt.man_bits) | man_field
    nbytes = max(8, 1 << (fmt.width - 1).bit_length())
    return out.astype(np.dtype(f"uint{nbytes}"))


def decode_np(bits: np.ndarray, fmt) -> np.ndarray:
    fmt = get_format(fmt)
    bits = np.asarray(bits).astype(np.uint64)
    sign = ((bits >> (fmt.exp_bits + fmt.man_bits)) & 1).astype(np.int64)
    exp_f = ((bits >> fmt.man_bits) & ((1 << fmt.exp_bits) - 1)).astype(np.int64)
    man_f = (bits & ((1 << fmt.man_bits) - 1)).astype(np.int64)
    is_sub = exp_f == 0
    is_special = exp_f == (1 << fmt.exp_bits) - 1
    with np.errstate(all="ignore"):
        val_norm = np.ldexp(1.0 + man_f / (1 << fmt.man_bits), exp_f - fmt.bias)
        val_sub = man_f * fmt.min_subnormal
    val = np.where(is_sub, val_sub, val_norm)
    val = np.where(is_special & (man_f == 0), np.inf, val)
    val = np.where(is_special & (man_f != 0), np.nan, val)
    return np.where(sign == 1, -val, val)
