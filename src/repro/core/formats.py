"""MiniFloat-NN format system (paper §III-A, Fig. 1).

Parameterized floating-point formats a la FPnew: any (exp_bits, man_bits)
pair defines a format; the paper's six formats are predefined. Two
implementations are provided and cross-tested:

  * a bit-exact *value-space* quantizer in pure JAX (`quantize`) — RNE,
    IEEE subnormals, overflow-to-inf — usable inside jit/pjit/Pallas;
  * exact bit-pattern `encode`/`decode` (numpy + JAX) for storage tests
    and for the integer-datapath ExSdotp oracle.

Native `ml_dtypes` counterparts (used on the performance path, where XLA/TPU
have hardware casts) are attached where they exist; the emulation layer is
authoritative for paper semantics.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

__all__ = [
    "MiniFloatFormat",
    "FP8", "FP8ALT", "FP16", "FP16ALT", "FP32", "FP64",
    "FP6E2M3", "FP6E3M2", "FP4E2M1",
    "FORMATS", "get_format", "quantize", "quantize_np",
    "encode_np", "decode_np", "encode", "decode",
    "MXFormat", "MXFP8E4M3", "MXFP8E5M2", "MXFP6E2M3", "MXFP6E3M2",
    "MXFP4E2M1", "MX_FORMATS", "get_mx_format",
    "E8M0_BIAS", "E8M0_NAN", "e8m0_encode_np", "e8m0_decode_np",
    "e8m0_encode", "e8m0_decode",
    "mx_group_scales_np", "mx_quantize_np", "mx_dequantize_np",
]


@dataclasses.dataclass(frozen=True)
class MiniFloatFormat:
    """An IEEE-754-style binary format with parametric field widths."""

    name: str
    exp_bits: int
    man_bits: int
    #: 'ieee'  -> overflow rounds to +-inf (paper semantics)
    #: 'saturate' -> overflow clamps to +-max_normal ("fn"-style, TPU casts)
    inf_behavior: str = "ieee"
    #: IEEE reserves the top exponent code for inf/NaN.  OCP MX sub-byte
    #: element formats (FP6/FP4) spend it on normals instead: no inf, no
    #: NaN — non-finite values are expressed at the *group* level via the
    #: E8M0 NaN scale.  With ``ieee_specials=False``, overflow (including
    #: true inf) clamps to ±max_normal and a NaN value encodes to the
    #: max-magnitude bit pattern (decode cannot round-trip it; the MX
    #: layer never encodes a NaN element because its group scale is NaN).
    ieee_specials: bool = True

    # ---- derived quantities ----------------------------------------
    @property
    def width(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def max_exp(self) -> int:  # unbiased exponent of largest normal
        return (1 << self.exp_bits) - (2 if self.ieee_specials else 1) - self.bias

    @property
    def min_exp(self) -> int:  # unbiased exponent of smallest normal
        return 1 - self.bias

    @property
    def precision(self) -> int:  # p = man_bits + 1 (hidden one); paper's p_src/p_dst
        return self.man_bits + 1

    @property
    def max_normal(self) -> float:
        return float(2.0 ** self.max_exp * (2.0 - 2.0 ** (-self.man_bits)))

    @property
    def min_normal(self) -> float:
        return float(2.0 ** self.min_exp)

    @property
    def min_subnormal(self) -> float:
        return float(2.0 ** (self.min_exp - self.man_bits))

    @property
    def ml_dtype(self) -> Optional[np.dtype]:
        """Native ml_dtypes counterpart, if one exists (exact match)."""
        key = (self.exp_bits, self.man_bits)
        if not self.ieee_specials:
            # OCP "fn" dtypes: no inf/NaN, saturating casts — only present
            # in newer ml_dtypes releases, hence the getattr guards.
            fn_table = {
                (2, 3): getattr(ml_dtypes, "float6_e2m3fn", None),
                (3, 2): getattr(ml_dtypes, "float6_e3m2fn", None),
                (2, 1): getattr(ml_dtypes, "float4_e2m1fn", None),
            }
            t = fn_table.get(key)
            return np.dtype(t) if t is not None else None
        table = {
            (5, 2): np.dtype(ml_dtypes.float8_e5m2),
            (4, 3): np.dtype(ml_dtypes.float8_e4m3),
            (5, 10): np.dtype(np.float16),
            (8, 7): np.dtype(ml_dtypes.bfloat16),
            (8, 23): np.dtype(np.float32),
            (11, 52): np.dtype(np.float64),
        }
        return table.get(key)

    @property
    def storage_dtype(self):
        """jnp dtype used to *store* tensors in this format on the perf path.

        For formats with no native dtype we store uint bit patterns.
        """
        md = self.ml_dtype
        if md is not None:
            return md
        return np.dtype(f"uint{max(8, 1 << (self.width - 1).bit_length())}")

    # ---- packed sub-byte storage (DESIGN.md §9) ---------------------
    @property
    def packed_bytes_per_element(self) -> float:
        """Bytes per element in *packed* storage: ``width / 8``.

        Sub-byte formats pack densely (FP4: two elements per byte, FP6:
        four elements in three bytes — ``kernels/pack.py``), so the
        honest byte accounting is fractional.
        """
        return self.width / 8

    @property
    def pack_align(self) -> int:
        """Element-count multiple a packed run must be: the smallest n
        with ``n * width`` a whole number of bytes (FP4 → 2, FP6 → 4,
        byte-multiples → 1)."""
        n = 1
        while (n * self.width) % 8:
            n += 1
        return n

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.name}(E{self.exp_bits}M{self.man_bits})"


# The paper's formats (Fig. 1 / §III-A). FP16ALT keeps bfloat16 widths but
# full IEEE rounding + subnormals, which ml_dtypes.bfloat16 implements.
FP8 = MiniFloatFormat("fp8", 5, 2)
FP8ALT = MiniFloatFormat("fp8alt", 4, 3)
FP16 = MiniFloatFormat("fp16", 5, 10)
FP16ALT = MiniFloatFormat("fp16alt", 8, 7)
FP32 = MiniFloatFormat("fp32", 8, 23)
FP64 = MiniFloatFormat("fp64", 11, 52)

# OCP MX sub-byte element formats (no inf/NaN; saturating overflow).
# Max normals: E2M3 -> 7.5, E3M2 -> 28, E2M1 -> 6.
FP6E2M3 = MiniFloatFormat("fp6e2m3", 2, 3, inf_behavior="saturate",
                          ieee_specials=False)
FP6E3M2 = MiniFloatFormat("fp6e3m2", 3, 2, inf_behavior="saturate",
                          ieee_specials=False)
FP4E2M1 = MiniFloatFormat("fp4e2m1", 2, 1, inf_behavior="saturate",
                          ieee_specials=False)

FORMATS = {f.name: f for f in (FP8, FP8ALT, FP16, FP16ALT, FP32, FP64,
                               FP6E2M3, FP6E3M2, FP4E2M1)}

#: ExSdotp source->destination pairing (paper Table I): expanding ops double
#: the width. 8-bit formats expand into FP16/FP16alt; 16-bit into FP32.
EXPANDING_DST = {
    "fp8": FP16, "fp8alt": FP16,
    "fp16": FP32, "fp16alt": FP32,
}


def get_format(name) -> MiniFloatFormat:
    if isinstance(name, MiniFloatFormat):
        return name
    return FORMATS[str(name).lower()]


# ---------------------------------------------------------------------------
# Value-space quantization (JAX, bit-exact, jit-safe)
# ---------------------------------------------------------------------------

def _exact_pow2(k: jax.Array) -> jax.Array:
    """2**k as f32, exact, for integer k in [-149, 127] (incl. subnormals).

    jnp.exp2 is an approximation on some backends (CPU XLA returns
    8192.004 for exp2(13)!), so powers of two are built from raw bits.
    """
    k = k.astype(jnp.int32)
    kn = jnp.clip(k, -126, 127)
    bits_norm = ((kn + 127) << 23).astype(jnp.uint32)
    val_norm = jax.lax.bitcast_convert_type(bits_norm, jnp.float32)
    shift = jnp.clip(k + 149, 0, 22).astype(jnp.uint32)
    val_sub = jax.lax.bitcast_convert_type(jnp.uint32(1) << shift, jnp.float32)
    return jnp.where(k < -126, val_sub, val_norm)


def _quantize_f32(x: jax.Array, fmt: MiniFloatFormat) -> jax.Array:
    """Round f32 values to the nearest representable value of ``fmt`` (RNE).

    Pure value-space arithmetic on exact powers of two, so every step is
    exact in f32 and the result is bit-identical to a hardware cast.
    """
    x = x.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    biased = ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32)
    e = biased - 127  # floor(log2|x|) for normal f32; -127 for f32 subnormals
    # quantization step: ulp at e, clamped at the subnormal plateau
    step_exp = jnp.maximum(e, fmt.min_exp) - fmt.man_bits
    # Scale by 2**(-step_exp), round, scale back. step_exp can reach -133
    # (fp16alt subnormals), beyond f32 exponent range, so split into two
    # exact power-of-two factors.
    half_a = step_exp // 2
    half_b = step_exp - half_a
    q = jnp.round(x * _exact_pow2(-half_a) * _exact_pow2(-half_b))
    q = q * _exact_pow2(half_a) * _exact_pow2(half_b)
    if fmt.min_exp - fmt.man_bits < -126:
        # fmt has representable values inside the f32-subnormal range
        # (fp16alt: down to 2^-133). XLA CPU runs with DAZ/FTZ, so those
        # must be produced via integer bit manipulation, not arithmetic.
        # Inputs with biased exponent 0 are exactly the affected set
        # (fp16alt.min_normal == f32 min normal).
        sub_step = fmt.min_exp - fmt.man_bits          # e.g. -133
        man = (bits & jnp.uint32(0x7FFFFF)).astype(jnp.float32)  # x = man*2^-149
        qi = jnp.round(man * _exact_pow2(jnp.full(x.shape, -149 - sub_step)))
        deep_bits = (qi.astype(jnp.uint32) << (149 + sub_step)) | (bits & jnp.uint32(0x80000000))
        deep = jax.lax.bitcast_convert_type(deep_bits, jnp.float32)
        q = jnp.where(biased == 0, deep, q)
    # overflow: beyond max_normal rounds to inf (ieee) or clamps (saturate);
    # formats with no inf encoding (ieee_specials=False) clamp true inf too
    max_normal = jnp.float32(fmt.max_normal)
    if fmt.inf_behavior == "ieee":
        over = jnp.where(jnp.isinf(x), x, jnp.sign(x) * jnp.inf)
    elif fmt.ieee_specials:
        over = jnp.where(jnp.isinf(x), x, jnp.sign(x) * max_normal)
    else:
        over = jnp.sign(x) * max_normal
    q = jnp.where(jnp.abs(q) > max_normal, over.astype(jnp.float32), q)
    # NaN propagates through the arithmetic already; +-0 preserved by round.
    return q


def quantize(x: jax.Array, fmt) -> jax.Array:
    """Quantize to ``fmt``'s representable set; returns float32 values."""
    fmt = get_format(fmt)
    if fmt.name == "fp32":
        return jnp.asarray(x, jnp.float32)
    if fmt.name == "fp64":
        return jnp.asarray(x, jnp.float32)  # f32 value already exact in f64
    return _quantize_f32(jnp.asarray(x), fmt)


# ---------------------------------------------------------------------------
# numpy mirror (oracle; float64 internal so it also serves 16/32-bit formats)
# ---------------------------------------------------------------------------

def quantize_np(x: np.ndarray, fmt) -> np.ndarray:
    fmt = get_format(fmt)
    x = np.asarray(x, np.float64)
    if fmt.name == "fp64":
        return x
    with np.errstate(all="ignore"):
        m, e = np.frexp(x)  # x = m * 2^e, 0.5<=|m|<1  => floor(log2|x|) = e-1
        e = e - 1
        step_exp = np.maximum(e, fmt.min_exp) - fmt.man_bits
        step = np.ldexp(1.0, step_exp.astype(np.int64))
        # np.round is round-half-even
        q = np.round(x / np.where(step == 0, 1.0, step)) * step
        if fmt.inf_behavior == "ieee":
            over = np.where(np.isinf(x), x, np.sign(x) * np.inf)
        elif fmt.ieee_specials:
            over = np.where(np.isinf(x), x, np.sign(x) * fmt.max_normal)
        else:
            over = np.sign(x) * fmt.max_normal
        q = np.where(np.abs(q) > fmt.max_normal, over, q)
        q = np.where(np.isnan(x), np.nan, q)
    return q


# ---------------------------------------------------------------------------
# Bit-pattern encode/decode (numpy; exact). Used by the ExSdotp oracle and
# storage round-trip tests for formats without a native dtype.
# ---------------------------------------------------------------------------

def encode_np(x: np.ndarray, fmt) -> np.ndarray:
    """Encode (already representable or arbitrary) values to fmt bit patterns."""
    fmt = get_format(fmt)
    q = quantize_np(np.asarray(x, np.float64), fmt)
    sign = (np.signbit(q)).astype(np.uint64)
    out = np.zeros(q.shape, np.uint64)
    aq = np.abs(q)
    nan = np.isnan(q)
    inf = np.isinf(q)
    sub = (aq < fmt.min_normal) & ~nan  # includes zero
    with np.errstate(all="ignore"):
        m, e = np.frexp(aq)
        e = e - 1
        # normals
        man_norm = np.rint((m * 2.0 - 1.0) * (1 << fmt.man_bits)).astype(np.uint64)
        exp_norm = (e + fmt.bias).astype(np.int64)
        # subnormals (and zero): value = man * 2^(min_exp - man_bits)
        man_sub = np.rint(aq / fmt.min_subnormal).astype(np.uint64)
    exp_field = np.where(sub, 0, np.clip(exp_norm, 0, (1 << fmt.exp_bits) - 1)).astype(np.uint64)
    man_field = np.where(sub, man_sub, man_norm).astype(np.uint64)
    if fmt.ieee_specials:
        exp_field = np.where(inf | nan, (1 << fmt.exp_bits) - 1, exp_field)
        man_field = np.where(inf, 0, man_field)
        man_field = np.where(nan, 1 << (fmt.man_bits - 1), man_field)  # quiet NaN
    else:
        # no special codes: quantize already clamped inf, NaN encodes to
        # the max-magnitude pattern (the MX group scale carries the NaN)
        exp_field = np.where(nan, (1 << fmt.exp_bits) - 1, exp_field)
        man_field = np.where(nan, (1 << fmt.man_bits) - 1, man_field)
    out = (sign << (fmt.exp_bits + fmt.man_bits)) | (exp_field << fmt.man_bits) | man_field
    nbytes = max(8, 1 << (fmt.width - 1).bit_length())
    return out.astype(np.dtype(f"uint{nbytes}"))


def decode_np(bits: np.ndarray, fmt) -> np.ndarray:
    fmt = get_format(fmt)
    bits = np.asarray(bits).astype(np.uint64)
    sign = ((bits >> (fmt.exp_bits + fmt.man_bits)) & 1).astype(np.int64)
    exp_f = ((bits >> fmt.man_bits) & ((1 << fmt.exp_bits) - 1)).astype(np.int64)
    man_f = (bits & ((1 << fmt.man_bits) - 1)).astype(np.int64)
    is_sub = exp_f == 0
    is_special = (exp_f == (1 << fmt.exp_bits) - 1) & fmt.ieee_specials
    with np.errstate(all="ignore"):
        val_norm = np.ldexp(1.0 + man_f / (1 << fmt.man_bits), exp_f - fmt.bias)
        val_sub = man_f * fmt.min_subnormal
    val = np.where(is_sub, val_sub, val_norm)
    val = np.where(is_special & (man_f == 0), np.inf, val)
    val = np.where(is_special & (man_f != 0), np.nan, val)
    return np.where(sign == 1, -val, val)


# ---------------------------------------------------------------------------
# Bit-pattern encode/decode (JAX; width <= 8). The jit-safe mirror of
# encode_np/decode_np, used by the packed sub-byte storage layer
# (kernels/pack.py): values <-> uint8 codes, then codes pack densely.
# ---------------------------------------------------------------------------

def encode(x: jax.Array, fmt) -> jax.Array:
    """Encode values to ``fmt`` bit patterns (uint8 codes; width <= 8).

    ``x`` is quantized to the representable set first, so arbitrary f32
    input is accepted; on already-representable values the cast is
    exact.  Bit-identical to ``encode_np``.
    """
    fmt = get_format(fmt)
    assert fmt.width <= 8, fmt
    q = _quantize_f32(jnp.asarray(x, jnp.float32), fmt)
    bits = jax.lax.bitcast_convert_type(q, jnp.uint32)
    aq = jnp.abs(q)
    nan = jnp.isnan(q)
    inf = jnp.isinf(q)
    # encode_np canonicalizes NaN to +nan (quantize_np); XLA keeps the
    # input NaN's sign bit, so drop it here to stay bit-identical
    sign = jnp.where(nan, 0, bits >> 31).astype(jnp.uint32)
    sub = (aq < jnp.float32(fmt.min_normal)) & ~nan  # includes zero
    # q is representable in fmt, hence f32-normal (or zero) for width<=8:
    # the fields fall straight out of the f32 bit pattern
    e = ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32) - 127
    man_norm = ((bits & jnp.uint32(0x7FFFFF))
                >> (23 - fmt.man_bits)).astype(jnp.uint32)
    exp_norm = jnp.clip(e + fmt.bias, 0, (1 << fmt.exp_bits) - 1)
    # subnormals (and zero): value = man * min_subnormal, exact pow2 ratio
    man_sub = jnp.round(
        aq * _exact_pow2(jnp.full(q.shape, fmt.man_bits - fmt.min_exp,
                                  jnp.int32))).astype(jnp.uint32)
    exp_field = jnp.where(sub, 0, exp_norm).astype(jnp.uint32)
    man_field = jnp.where(sub, man_sub, man_norm)
    top = jnp.uint32((1 << fmt.exp_bits) - 1)
    if fmt.ieee_specials:
        exp_field = jnp.where(inf | nan, top, exp_field)
        man_field = jnp.where(inf, 0, man_field)
        man_field = jnp.where(nan, 1 << (fmt.man_bits - 1), man_field)
    else:
        # no special codes: quantize already clamped inf, NaN encodes to
        # the max-magnitude pattern (the MX group scale carries the NaN)
        exp_field = jnp.where(nan, top, exp_field)
        man_field = jnp.where(nan, (1 << fmt.man_bits) - 1, man_field)
    out = ((sign << (fmt.exp_bits + fmt.man_bits))
           | (exp_field << fmt.man_bits) | man_field)
    return out.astype(jnp.uint8)


def decode(code: jax.Array, fmt) -> jax.Array:
    """Decode ``fmt`` bit patterns (uint8 codes) to f32 values.

    Bit-identical to ``decode_np`` (and to ``quantize``'s value set).
    """
    fmt = get_format(fmt)
    assert fmt.width <= 8, fmt
    c = jnp.asarray(code).astype(jnp.int32)
    sign = (c >> (fmt.exp_bits + fmt.man_bits)) & 1
    exp_f = (c >> fmt.man_bits) & ((1 << fmt.exp_bits) - 1)
    man_f = c & ((1 << fmt.man_bits) - 1)
    # exact in f32: mantissa fits, exponents are normal-range
    val_norm = ((1.0 + man_f.astype(jnp.float32) * (2.0 ** -fmt.man_bits))
                * _exact_pow2(exp_f - fmt.bias))
    val_sub = man_f.astype(jnp.float32) * jnp.float32(fmt.min_subnormal)
    val = jnp.where(exp_f == 0, val_sub, val_norm)
    if fmt.ieee_specials:
        sp = exp_f == (1 << fmt.exp_bits) - 1
        val = jnp.where(sp & (man_f == 0), jnp.float32(jnp.inf), val)
        val = jnp.where(sp & (man_f != 0), jnp.float32(jnp.nan), val)
    return jnp.where(sign == 1, -val, val)


# ---------------------------------------------------------------------------
# MX formats: element format × E8M0 shared scale × group size (DESIGN.md §8)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MXFormat:
    """An OCP-MX-style block format: ``group`` consecutive elements along
    the contraction (K) axis share one E8M0 scale (8 exponent bits, no
    mantissa, no sign — a pure power of two), each element stored in
    ``elem``.  The shared scale is the Flexpoint/Graphcore mechanism that
    makes sub-byte training survive real activation distributions: the
    dynamic-range window tracks each 32-element group, not the tensor.

    Differences from ``BlockScaleConfig`` tiles (DESIGN.md §3): groups are
    1×``group`` strips along K only (not 2-D tiles), the scale is a
    *storable 8-bit* E8M0 code rather than a free f32, and a non-finite
    group encodes scale=NaN (E8M0 0xFF) — the whole group reads back NaN —
    instead of the neutral-scale poison-propagation of the f32 path.
    """

    name: str
    elem: MiniFloatFormat
    group: int = 32

    @property
    def bits_per_element(self) -> float:
        """Storage cost incl. the amortized shared scale."""
        return self.elem.width + 8 / self.group

    @property
    def packed_bytes_per_element(self) -> float:
        """Bytes per element in packed storage, incl. the amortized E8M0
        byte (one uint8 per ``group`` elements): the wire/HBM cost the
        packed payload layer (``kernels/pack.py``) actually realizes."""
        return self.elem.packed_bytes_per_element + 1.0 / self.group

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.name}({self.elem.name}xg{self.group})"


MXFP8E4M3 = MXFormat("mxfp8e4m3", FP8ALT)
MXFP8E5M2 = MXFormat("mxfp8e5m2", FP8)
MXFP6E2M3 = MXFormat("mxfp6e2m3", FP6E2M3)
MXFP6E3M2 = MXFormat("mxfp6e3m2", FP6E3M2)
MXFP4E2M1 = MXFormat("mxfp4e2m1", FP4E2M1)

MX_FORMATS = {f.name: f for f in (MXFP8E4M3, MXFP8E5M2, MXFP6E2M3,
                                  MXFP6E3M2, MXFP4E2M1)}


def get_mx_format(name) -> MXFormat:
    if isinstance(name, MXFormat):
        return name
    return MX_FORMATS[str(name).lower()]


# E8M0 scale encoding: value = 2**(code - 127) for code 0..254; 255 = NaN.
E8M0_BIAS = 127
E8M0_NAN = 255


def e8m0_encode_np(s: np.ndarray) -> np.ndarray:
    """Encode power-of-two f32 scales (or NaN) to E8M0 uint8 codes."""
    s = np.asarray(s, np.float64)
    nan = ~np.isfinite(s)
    with np.errstate(all="ignore"):
        m, e = np.frexp(s)  # s = m * 2^e, m == 0.5 exactly for pow2 s
    assert np.all(nan | ((m == 0.5) & (s > 0))), "E8M0 scales must be pow2"
    code = np.clip(e - 1 + E8M0_BIAS, 0, 254)
    return np.where(nan, E8M0_NAN, code).astype(np.uint8)


def e8m0_decode_np(code: np.ndarray) -> np.ndarray:
    code = np.asarray(code).astype(np.int64)
    val = np.ldexp(1.0, np.clip(code, 0, 254) - E8M0_BIAS)
    return np.where(code == E8M0_NAN, np.nan, val)


def e8m0_encode(s: jax.Array) -> jax.Array:
    """JAX mirror of ``e8m0_encode_np``: pow2 f32 scales (or NaN) to
    E8M0 uint8 codes.  For a normal pow2 the code *is* the f32 biased
    exponent field; NaN's all-ones exponent field is exactly the E8M0
    NaN code (255), so the encode is a single bit extraction.  This is
    what lets scale grids ride collectives at one byte per group.
    """
    bits = jax.lax.bitcast_convert_type(s.astype(jnp.float32), jnp.uint32)
    return ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.uint8)


def e8m0_decode(code: jax.Array) -> jax.Array:
    """JAX mirror of ``e8m0_decode_np``: uint8 codes to f32 scales
    (exact — pow2), code 255 to NaN."""
    c = jnp.asarray(code).astype(jnp.int32)
    val = _exact_pow2(jnp.clip(c, 0, 254) - E8M0_BIAS)
    return jnp.where(c == E8M0_NAN, jnp.float32(jnp.nan), val)


def _pow2_ceil_np(v: np.ndarray) -> np.ndarray:
    """Smallest power of two >= v for finite v > 0 (exact, via frexp)."""
    with np.errstate(all="ignore"):
        m, e = np.frexp(v)
    return np.where(m == 0.5, np.ldexp(1.0, e - 1), np.ldexp(1.0, e))


def mx_group_scales_np(x: np.ndarray, mx) -> np.ndarray:
    """E8M0 group scales for ``x[..., K]`` — the numpy oracle.

    Mirrors ``core.scaling.compute_group_scales`` bit for bit: the
    amax/max_normal division is performed in float32 (matching the
    kernel's arithmetic), the pow2-ceil is exact, and the result is
    clamped to the E8M0-representable [2^-126, 2^127] window the JAX
    ``_pow2_ceil`` produces.  amax == 0 -> neutral scale 1; non-finite
    amax -> NaN (the E8M0 NaN encoding: the whole group reads back NaN).
    """
    mx = get_mx_format(mx)
    *lead, k = x.shape
    assert k % mx.group == 0, (k, mx.group)
    xg = np.abs(np.asarray(x, np.float32)).reshape(*lead, k // mx.group,
                                                   mx.group)
    amax = xg.max(axis=-1)
    with np.errstate(all="ignore"):
        r = (amax / np.float32(mx.elem.max_normal)).astype(np.float32)
        s = _pow2_ceil_np(np.maximum(r.astype(np.float64), 2.0 ** -126))
    s = np.minimum(s, 2.0 ** 127)
    s = np.where(amax == 0, 1.0, s)
    return np.where(np.isfinite(amax), s, np.nan)


def mx_quantize_np(x: np.ndarray, mx):
    """Group-quantize ``x[..., K]``: returns ``(q, s)`` with ``q`` the
    element-format values of ``x / s`` (value space, float64 carrier) and
    ``s`` the per-group scales (``x.shape[:-1] + (K//group,)``).  The
    division is done in float32 — exact for pow2 scales — so the kernel
    path is bit-comparable.  A NaN scale poisons its whole group."""
    mx = get_mx_format(mx)
    s = mx_group_scales_np(x, mx)
    se = np.repeat(s, mx.group, axis=-1).reshape(x.shape)
    with np.errstate(all="ignore"):
        scaled = (np.asarray(x, np.float32) / se.astype(np.float32))
    return quantize_np(scaled.astype(np.float64), mx.elem), s


def mx_dequantize_np(q: np.ndarray, s: np.ndarray, mx) -> np.ndarray:
    mx = get_mx_format(mx)
    se = np.repeat(np.asarray(s, np.float64), mx.group, axis=-1).reshape(
        q.shape)
    with np.errstate(all="ignore"):
        return np.asarray(q, np.float64) * se
