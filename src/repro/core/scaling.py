"""Dynamic loss scaling — required hygiene for narrow-range gradient
formats (fp16 / FP8-E5M2 per-tensor-scaled).

Classic scheme: multiply the loss by ``scale``; unscale gradients; if any
gradient is non-finite, skip the update and halve the scale; after
``growth_interval`` clean steps, double it (capped).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["loss_scale_init", "check_and_update_scale"]


def loss_scale_init(initial: float = 2.0 ** 15):
    return {"scale": jnp.float32(initial),
            "good_steps": jnp.zeros((), jnp.int32)}


def check_and_update_scale(state, grads, *, growth_interval: int = 2000,
                           factor: float = 2.0, max_scale: float = 2.0 ** 24):
    """Returns (unscaled_grads, new_state, skip_update)."""
    finite = jnp.array(True)
    for g in jax.tree.leaves(grads):
        finite &= jnp.all(jnp.isfinite(g.astype(jnp.float32)))
    scale = state["scale"]
    unscaled = jax.tree.map(
        lambda g: (g.astype(jnp.float32) / scale).astype(g.dtype), grads)
    good = jnp.where(finite, state["good_steps"] + 1, 0)
    grow = good >= growth_interval
    new_scale = jnp.where(
        ~finite, jnp.maximum(scale / factor, 1.0),
        jnp.where(grow, jnp.minimum(scale * factor, max_scale), scale))
    new_state = {"scale": new_scale,
                 "good_steps": jnp.where(grow, 0, good)}
    return unscaled, new_state, ~finite
