"""Scaling machinery for narrow formats (DESIGN.md §3).

Two independent mechanisms live here:

* **dynamic loss scaling** — required hygiene for narrow-range gradient
  formats (fp16 / FP8-E5M2 per-tensor-scaled).  Classic scheme: multiply
  the loss by ``scale``; unscale gradients; if any gradient is
  non-finite, skip the update and halve the scale; after
  ``growth_interval`` clean steps, double it (capped).

* **per-block quantization scales** — one dequant factor per
  (row-tile × K-tile) of a GEMM operand, instead of one per tensor.
  Flexpoint-style shared exponents and Graphcore's block formats both
  show this is what makes 8-bit training robust to outliers: the scale
  tracks the local amax, so a single huge activation no longer flushes
  the rest of the tensor into the subnormal mud.  ``BlockScaleConfig``
  is the knob threaded through policy → linear → kernels; scales default
  to powers of two (MX-style), which makes the quantize/dequant rescale
  *exact* — quantization error then comes only from the mantissa
  rounding, never from the scaling itself.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["loss_scale_init", "check_and_update_scale",
           "BlockScaleConfig", "compute_block_scales", "apply_block_scales",
           "compute_group_scales", "apply_group_scales",
           "expand_group_scales",
           "block_loss_scale_init", "check_and_update_block_scales"]


# ---------------------------------------------------------------------------
# Per-block quantization scales (DESIGN.md §3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockScaleConfig:
    """Granularity + rounding of per-block dequantization scales.

    A GEMM operand ``A[M, K]`` gets one f32 scale per
    ``(block_m, block_k)`` tile (``B[K, N]`` per ``(block_k, block_n)``),
    so the fused kernel can dequantize each partial product at
    accumulator granularity: the fp32 accumulator stays wide across the
    whole K loop and is rounded once — eq. 1's structure, per block.
    """

    #: row-tile of the left operand / output rows
    block_m: int = 128
    #: column-tile of the right operand / output columns
    block_n: int = 128
    #: K-tile shared by both operands (scale granularity on the
    #: contraction axis == the kernel's accumulation granularity)
    block_k: int = 128
    #: headroom: quantized amax lands at ``margin * max_normal``
    margin: float = 1.0
    #: round scales up to powers of two (MX-style shared exponents);
    #: pow2 rescaling is exact, so dequant introduces no extra rounding
    pow2: bool = True

    @classmethod
    def from_policy(cls, policy) -> "BlockScaleConfig | None":
        """The config a ``Policy`` asks for (None = per-tensor scaling).

        ``margin``/``pow2`` come from the policy's ``block_margin`` /
        ``block_pow2`` fields, so policies can express quantization
        headroom instead of the fields being silently dropped here.
        """
        n = int(getattr(policy, "block_scale", 0) or 0)
        if n <= 0:
            return None
        return cls(block_m=n, block_n=n, block_k=n,
                   margin=float(getattr(policy, "block_margin", 1.0)),
                   pow2=bool(getattr(policy, "block_pow2", True)))


def _pow2_ceil(x: jax.Array) -> jax.Array:
    """Smallest power of two >= x, exact, for normal-range f32 x > 0.

    Built from exponent bits (``jnp.exp2`` is approximate on CPU XLA).
    """
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    exp = ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32)   # biased
    man = bits & jnp.uint32(0x7FFFFF)
    # 2**(e+1) unless x is already an exact power of two
    exp = jnp.where(man == 0, exp, exp + 1)
    pow2 = jax.lax.bitcast_convert_type(
        (jnp.clip(exp, 1, 254).astype(jnp.uint32) << 23), jnp.float32)
    return pow2


def compute_block_scales(x: jax.Array, block_r: int, block_c: int,
                         q_dtype, *, margin: float = 1.0,
                         pow2: bool = True) -> jax.Array:
    """Per-(block_r × block_c)-tile dequant scales for ``x[..., R, C]``.

    Returns ``s[..., R//block_r, C//block_c]`` (f32) such that ``x / s``
    (broadcast per tile) fills ``q_dtype``'s range: quantized ≈ x / s,
    dequantized = quantized * s.  All-zero tiles get scale 1.  Shapes
    must already be padded to tile multiples (``kernels.ops`` pads).

    Leading dims are batch: tiles never cross them, so a 3D activation
    gets per-(batch, row-tile × col-tile) granularity at native rank —
    sequence-sharded leading dims survive without a flatten.

    Tiles whose amax is non-finite get scale 1 so the ``inf``/``NaN``
    elements propagate through quantize → dequant into the output (and
    from there to ``check_and_update_scale``'s skip logic) instead of
    being laundered into zeros by an ``inf`` scale.
    """
    *lead, r, c = x.shape
    assert r % block_r == 0 and c % block_c == 0, ((r, c), (block_r, block_c))
    xb = jnp.abs(x.astype(jnp.float32)).reshape(
        *lead, r // block_r, block_r, c // block_c, block_c)
    amax = jnp.max(xb, axis=(-3, -1))
    max_normal = jnp.float32(jnp.finfo(q_dtype).max)
    s = amax / (max_normal * jnp.float32(margin))
    if pow2:
        s = _pow2_ceil(jnp.maximum(s, jnp.float32(2.0 ** -126)))
    return jnp.where((amax > 0) & jnp.isfinite(amax), s, jnp.float32(1.0))


def apply_block_scales(x: jax.Array, s: jax.Array, block_r: int,
                       block_c: int, *, inverse: bool = False) -> jax.Array:
    """Broadcast per-tile scales over ``x[..., R, C]``: ``x * s`` per
    (block_r × block_c) tile (``inverse=True`` divides — the quantize
    direction). ``s[..., R//block_r, C//block_c]`` as produced by
    ``compute_block_scales``; leading dims are batch."""
    *lead, r, c = x.shape
    xb = x.reshape(*lead, r // block_r, block_r, c // block_c, block_c)
    st = s[..., :, None, :, None]
    xb = xb / st if inverse else xb * st
    return xb.reshape(x.shape)


# ---------------------------------------------------------------------------
# MX group scales: shared exponents over groups of 32 along K (DESIGN.md §8)
# ---------------------------------------------------------------------------

def compute_group_scales(x: jax.Array, group: int, elem_max: float,
                         *, nan_scale: bool = True) -> jax.Array:
    """E8M0 shared scales for ``x[..., K]``: one power-of-two f32 scale
    per ``group`` consecutive elements of the last axis.

    Returns ``s[..., K//group]`` such that ``x / s`` (broadcast per
    group) fills the element format's range ``[-elem_max, elem_max]``.
    E8M0 semantics: the scale is *pow2-only* (no mantissa — ``_pow2_ceil``
    on exponent bits, so the quantize/dequant rescale is exact) and fits
    the 8-bit biased-exponent code: values clamp to [2^-126, 2^127]
    (within E8M0's [2^-127, 2^127] window).  All-zero groups get the
    neutral scale 1.  A group whose amax is non-finite gets scale NaN —
    the E8M0 NaN encoding (0xFF): the whole group reads back NaN, which
    propagates to ``check_and_update_scale``'s skip logic.  Pass
    ``nan_scale=False`` for the f32-path convention (neutral scale 1,
    per-element poison) instead.

    Unlike ``compute_block_scales``' 2-D tiles, groups are 1×``group``
    strips along the contraction axis only — K-granular, M-exact — so a
    single outlier perturbs at most 31 neighbours' quantization.
    """
    *lead, k = x.shape
    assert k % group == 0, (k, group)
    xg = jnp.abs(x.astype(jnp.float32)).reshape(*lead, k // group, group)
    amax = jnp.max(xg, axis=-1)
    s = _pow2_ceil(jnp.maximum(amax / jnp.float32(elem_max),
                               jnp.float32(2.0 ** -126)))
    s = jnp.where(amax > 0, s, jnp.float32(1.0))
    bad = jnp.float32(jnp.nan) if nan_scale else jnp.float32(1.0)
    return jnp.where(jnp.isfinite(amax), s, bad)


def expand_group_scales(s: jax.Array, group: int) -> jax.Array:
    """Broadcast per-group scales to element resolution along the last
    axis: ``s[..., K/group] -> [..., K]``, each scale repeated over its
    1×``group`` strip.  The single definition of the group layout —
    the fused kernels, the jnp refs and the GEMM wrappers all expand
    through here, so kernel/oracle bit-exactness can't silently
    desynchronize on a layout change."""
    return jnp.repeat(s, group, axis=-1)


def apply_group_scales(x: jax.Array, s: jax.Array, group: int,
                       *, inverse: bool = False) -> jax.Array:
    """Broadcast per-group scales over ``x[..., K]``: ``x * s`` per
    ``group``-element strip (``inverse=True`` divides — the quantize
    direction).  Exact for pow2 scales."""
    se = expand_group_scales(s, group).reshape(x.shape)
    return x / se if inverse else x * se


def loss_scale_init(initial: float = 2.0 ** 15):
    return {"scale": jnp.float32(initial),
            "good_steps": jnp.zeros((), jnp.int32)}


def check_and_update_scale(state, grads, *, growth_interval: int = 2000,
                           factor: float = 2.0, max_scale: float = 2.0 ** 24):
    """Returns (unscaled_grads, new_state, skip_update)."""
    finite = jnp.array(True)
    for g in jax.tree.leaves(grads):
        finite &= jnp.all(jnp.isfinite(g.astype(jnp.float32)))
    scale = state["scale"]
    unscaled = jax.tree.map(
        lambda g: (g.astype(jnp.float32) / scale).astype(g.dtype), grads)
    good = jnp.where(finite, state["good_steps"] + 1, 0)
    grow = good >= growth_interval
    new_scale = jnp.where(
        ~finite, jnp.maximum(scale / factor, 1.0),
        jnp.where(grow, jnp.minimum(scale * factor, max_scale), scale))
    new_state = {"scale": new_scale,
                 "good_steps": jnp.where(grow, 0, good)}
    return unscaled, new_state, ~finite


# ---------------------------------------------------------------------------
# Per-block dynamic loss scaling (DESIGN.md §8)
# ---------------------------------------------------------------------------

def block_loss_scale_init(n_blocks: int, initial: float = 2.0 ** 15):
    """Per-row-tile loss-scale state: ``n_blocks`` independent scales.

    The classic scheme keys the *whole step* off the worst tensor: one
    inf anywhere halves the single global scale and skips everything.
    With per-block state, each row tile (e.g. a microbatch's slice of
    the token axis) carries its own scale and good-step counter, so a
    divergence in one block backs off only that block's scale while the
    rest keep growing — the loss-scaling analogue of per-block
    quantization scales.
    """
    return {"scale": jnp.full((n_blocks,), initial, jnp.float32),
            "good_steps": jnp.zeros((n_blocks,), jnp.int32)}


def check_and_update_block_scales(state, grad, *, growth_interval: int = 2000,
                                  factor: float = 2.0,
                                  max_scale: float = 2.0 ** 24):
    """Per-row-tile variant of ``check_and_update_scale``.

    ``grad``'s leading axis is split into ``n_blocks = state['scale'].shape[0]``
    equal contiguous row tiles, each scaled by its own ``scale[b]``.
    Returns ``(unscaled, new_state, skip)`` where ``skip[b]`` is True for
    tiles whose gradients contain inf/NaN — their unscaled values are not
    trustworthy and their scale has been backed off (floor 1.0); finite
    tiles follow the usual growth schedule (×``factor`` after
    ``growth_interval`` clean steps, capped at ``max_scale``).

    Composes with the global skip logic: ``skip.any()`` is exactly the
    ``check_and_update_scale`` skip decision, so a trainer can either
    mask per-tile updates or fall back to skipping the whole step.
    """
    n = state["scale"].shape[0]
    m = grad.shape[0]
    assert m % n == 0, (m, n)
    gb = grad.astype(jnp.float32).reshape(n, m // n, *grad.shape[1:])
    finite = jnp.all(jnp.isfinite(gb), axis=tuple(range(1, gb.ndim)))
    scale = state["scale"]
    bshape = (n,) + (1,) * (gb.ndim - 1)
    unscaled = (gb / scale.reshape(bshape)).reshape(grad.shape).astype(
        grad.dtype)
    good = jnp.where(finite, state["good_steps"] + 1, 0)
    grow = good >= growth_interval
    new_scale = jnp.where(
        ~finite, jnp.maximum(scale / factor, 1.0),
        jnp.where(grow, jnp.minimum(scale * factor, max_scale), scale))
    new_state = {"scale": new_scale,
                 "good_steps": jnp.where(grow, jnp.zeros_like(good), good)}
    return unscaled, new_state, ~finite
