"""Scaling machinery for narrow formats (DESIGN.md §3).

Two independent mechanisms live here:

* **dynamic loss scaling** — required hygiene for narrow-range gradient
  formats (fp16 / FP8-E5M2 per-tensor-scaled).  Classic scheme: multiply
  the loss by ``scale``; unscale gradients; if any gradient is
  non-finite, skip the update and halve the scale; after
  ``growth_interval`` clean steps, double it (capped).

* **per-block quantization scales** — one dequant factor per
  (row-tile × K-tile) of a GEMM operand, instead of one per tensor.
  Flexpoint-style shared exponents and Graphcore's block formats both
  show this is what makes 8-bit training robust to outliers: the scale
  tracks the local amax, so a single huge activation no longer flushes
  the rest of the tensor into the subnormal mud.  ``BlockScaleConfig``
  is the knob threaded through policy → linear → kernels; scales default
  to powers of two (MX-style), which makes the quantize/dequant rescale
  *exact* — quantization error then comes only from the mantissa
  rounding, never from the scaling itself.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["loss_scale_init", "check_and_update_scale",
           "BlockScaleConfig", "compute_block_scales", "apply_block_scales"]


# ---------------------------------------------------------------------------
# Per-block quantization scales (DESIGN.md §3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockScaleConfig:
    """Granularity + rounding of per-block dequantization scales.

    A GEMM operand ``A[M, K]`` gets one f32 scale per
    ``(block_m, block_k)`` tile (``B[K, N]`` per ``(block_k, block_n)``),
    so the fused kernel can dequantize each partial product at
    accumulator granularity: the fp32 accumulator stays wide across the
    whole K loop and is rounded once — eq. 1's structure, per block.
    """

    #: row-tile of the left operand / output rows
    block_m: int = 128
    #: column-tile of the right operand / output columns
    block_n: int = 128
    #: K-tile shared by both operands (scale granularity on the
    #: contraction axis == the kernel's accumulation granularity)
    block_k: int = 128
    #: headroom: quantized amax lands at ``margin * max_normal``
    margin: float = 1.0
    #: round scales up to powers of two (MX-style shared exponents);
    #: pow2 rescaling is exact, so dequant introduces no extra rounding
    pow2: bool = True

    @classmethod
    def from_policy(cls, policy) -> "BlockScaleConfig | None":
        """The config a ``Policy`` asks for (None = per-tensor scaling).

        ``margin``/``pow2`` come from the policy's ``block_margin`` /
        ``block_pow2`` fields, so policies can express quantization
        headroom instead of the fields being silently dropped here.
        """
        n = int(getattr(policy, "block_scale", 0) or 0)
        if n <= 0:
            return None
        return cls(block_m=n, block_n=n, block_k=n,
                   margin=float(getattr(policy, "block_margin", 1.0)),
                   pow2=bool(getattr(policy, "block_pow2", True)))


def _pow2_ceil(x: jax.Array) -> jax.Array:
    """Smallest power of two >= x, exact, for normal-range f32 x > 0.

    Built from exponent bits (``jnp.exp2`` is approximate on CPU XLA).
    """
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    exp = ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32)   # biased
    man = bits & jnp.uint32(0x7FFFFF)
    # 2**(e+1) unless x is already an exact power of two
    exp = jnp.where(man == 0, exp, exp + 1)
    pow2 = jax.lax.bitcast_convert_type(
        (jnp.clip(exp, 1, 254).astype(jnp.uint32) << 23), jnp.float32)
    return pow2


def compute_block_scales(x: jax.Array, block_r: int, block_c: int,
                         q_dtype, *, margin: float = 1.0,
                         pow2: bool = True) -> jax.Array:
    """Per-(block_r × block_c)-tile dequant scales for ``x[..., R, C]``.

    Returns ``s[..., R//block_r, C//block_c]`` (f32) such that ``x / s``
    (broadcast per tile) fills ``q_dtype``'s range: quantized ≈ x / s,
    dequantized = quantized * s.  All-zero tiles get scale 1.  Shapes
    must already be padded to tile multiples (``kernels.ops`` pads).

    Leading dims are batch: tiles never cross them, so a 3D activation
    gets per-(batch, row-tile × col-tile) granularity at native rank —
    sequence-sharded leading dims survive without a flatten.

    Tiles whose amax is non-finite get scale 1 so the ``inf``/``NaN``
    elements propagate through quantize → dequant into the output (and
    from there to ``check_and_update_scale``'s skip logic) instead of
    being laundered into zeros by an ``inf`` scale.
    """
    *lead, r, c = x.shape
    assert r % block_r == 0 and c % block_c == 0, ((r, c), (block_r, block_c))
    xb = jnp.abs(x.astype(jnp.float32)).reshape(
        *lead, r // block_r, block_r, c // block_c, block_c)
    amax = jnp.max(xb, axis=(-3, -1))
    max_normal = jnp.float32(jnp.finfo(q_dtype).max)
    s = amax / (max_normal * jnp.float32(margin))
    if pow2:
        s = _pow2_ceil(jnp.maximum(s, jnp.float32(2.0 ** -126)))
    return jnp.where((amax > 0) & jnp.isfinite(amax), s, jnp.float32(1.0))


def apply_block_scales(x: jax.Array, s: jax.Array, block_r: int,
                       block_c: int, *, inverse: bool = False) -> jax.Array:
    """Broadcast per-tile scales over ``x[..., R, C]``: ``x * s`` per
    (block_r × block_c) tile (``inverse=True`` divides — the quantize
    direction). ``s[..., R//block_r, C//block_c]`` as produced by
    ``compute_block_scales``; leading dims are batch."""
    *lead, r, c = x.shape
    xb = x.reshape(*lead, r // block_r, block_r, c // block_c, block_c)
    st = s[..., :, None, :, None]
    xb = xb / st if inverse else xb * st
    return xb.reshape(x.shape)


def loss_scale_init(initial: float = 2.0 ** 15):
    return {"scale": jnp.float32(initial),
            "good_steps": jnp.zeros((), jnp.int32)}


def check_and_update_scale(state, grads, *, growth_interval: int = 2000,
                           factor: float = 2.0, max_scale: float = 2.0 ** 24):
    """Returns (unscaled_grads, new_state, skip_update)."""
    finite = jnp.array(True)
    for g in jax.tree.leaves(grads):
        finite &= jnp.all(jnp.isfinite(g.astype(jnp.float32)))
    scale = state["scale"]
    unscaled = jax.tree.map(
        lambda g: (g.astype(jnp.float32) / scale).astype(g.dtype), grads)
    good = jnp.where(finite, state["good_steps"] + 1, 0)
    grow = good >= growth_interval
    new_scale = jnp.where(
        ~finite, jnp.maximum(scale / factor, 1.0),
        jnp.where(grow, jnp.minimum(scale * factor, max_scale), scale))
    new_state = {"scale": new_scale,
                 "good_steps": jnp.where(grow, 0, good)}
    return unscaled, new_state, ~finite
