"""ExSdotp GEMM — Pallas TPU kernel (the SIMD ExSdotp unit writ MXU-large).

Mapping of the paper's unit onto the TPU memory/compute hierarchy
(DESIGN.md §2):

  * narrow source operands (fp8/fp8alt/fp16/fp16alt) live in HBM and are
    streamed tile-by-tile into VMEM — the paper's register-file-packing win
    (Fig. 2) becomes a 2x HBM-bandwidth win;
  * the MXU multiplies narrow inputs and accumulates *expanded* into an
    fp32 VMEM scratch accumulator — the paper's e_2w accumulator, kept at
    full width across the whole K loop (a many-term ExSdotp chain with no
    intermediate rounding, i.e. even stronger than eq. 1);
  * the single downcast on the final K step is the unit's one
    normalization/rounding stage;
  * BlockSpec index maps play the role of Snitch's SSR streamers and the
    grid that of FREP hardware loops.

Tiling: (bm, bk) x (bk, bn) blocks, 128-aligned for the 128x128 MXU.
Default bk is 512 for 1-byte sources / 256 for 2-byte sources, keeping the
working set (A + B + acc + out) under ~0.5 MiB of VMEM, far below the
16 MiB/core budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["exsdotp_gemm_pallas", "default_blocks"]


def default_blocks(m: int, n: int, k: int, src_bytes: int) -> tuple[int, int, int]:
    """MXU-aligned block sizes; shrink to the problem if it is small."""
    bm = min(128, m)
    bn = min(128, n)
    bk = min(512 // src_bytes * 1 if src_bytes == 1 else 256, k)
    # blocks must divide padded dims; ops.py pads to multiples.
    return bm, bn, max(bk, 1)


def _kernel(a_ref, b_ref, scale_ref, o_ref, acc_ref):
    """One (i, j, k) grid step: acc += A_ik @ B_kj (fp32), write on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # expanding multiply: decode the minifloat tiles into the wide datapath
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _write():
        # single rounding into the destination format (+ dequant rescale)
        o_ref[...] = (acc_ref[...] * scale_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("out_dtype", "block_m", "block_n", "block_k", "interpret"))
def exsdotp_gemm_pallas(a: jax.Array, b: jax.Array, scale: jax.Array,
                        *, out_dtype=jnp.float32,
                        block_m: int = 128, block_n: int = 128,
                        block_k: int = 512, interpret: bool = False) -> jax.Array:
    """C[M,N] = downcast(scale * sum_k A[M,K] B[K,N]) with fp32 accumulation.

    ``a``/``b`` may be any narrow dtype XLA can upcast (float8_e5m2,
    float8_e4m3, float16, bfloat16). ``scale`` is a (1,1) f32 dequant factor
    (product of the per-tensor quantization scales), fused into the final
    write — the paper's ExSdotp structure (DESIGN.md §2): multiply
    narrow, accumulate f32 across the K grid, round once.

    Tile-legality contract (DESIGN.md §2/§14): shapes must be multiples
    of the blocks (``ops.exsdotp_gemm`` pads); ``block_m`` is a sublane
    8-multiple while ``block_n``/``block_k`` land on lane axes and must
    be 128-multiples on compiled TPU (interp/CPU CI masks violations).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, jnp.asarray(scale, jnp.float32).reshape(1, 1))
