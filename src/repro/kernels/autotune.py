"""Tile/layout autotuning for the packed kernels (DESIGN.md §14).

The packed Pallas kernels ship with static 128-ish tile heuristics
(``ops.blockscale_blocks`` / ``mx_packed_blocks`` / ``attention_blocks``)
that respect the compiled-TPU legality floors but were never *measured*:
nothing in the stack knew whether a 128³ tile or a 32×256×1024 tile is
closer to the roofline on a given backend.  This module closes that gap
with a sweep-and-cache autotuner:

* **Candidate enumeration** (``gemm_tile_candidates`` /
  ``attention_tile_candidates``) — every swept tile is *legal by
  construction*: sublane axes (M / block_q) are 8-multiples, lane axes
  (N, K / block_k) are 128-multiples, packed K-tiles are multiples of
  every participating codec's ``lane_unit`` (FP8 → 128, FP4 → 256, FP6
  → 512 elements — the floor below which a packed byte run stops being
  a 128-multiple lane tile) *and* of the MX group, tiles never exceed
  the minimally padded problem, and the per-step VMEM working set stays
  under a budget.  Attention candidates must divide S/T exactly (those
  kernels assert divisibility instead of padding).  The packed-GEMM
  sweep additionally carries a *layout* axis: each tile shape is tried
  with the grid-pipelined K-loop and with the double-buffered manual-DMA
  K-loop (``mx_gemm_packed_pallas(double_buffer=True)``) — bitwise
  equal, different streaming schedules.

* **Measurement** (``autotune``) — median-of-iters wall clock through
  ``time_us_median`` (every iteration synchronized with
  ``block_until_ready`` — async dispatch must not leak into the number;
  the median discards scheduler outliers).  The bench callable is
  injected, so tests drive the machinery with deterministic stubs.

* **Persistent cache** — one JSON file per kernel under
  ``benchmarks/baselines/tune/`` (override with ``REPRO_TUNE_DIR``),
  keyed per (shape, formats, backend).  Entries from another backend
  never apply (the backend is part of the key), and a version bump
  invalidates the whole file.  The in-process memo makes repeat lookups
  free; a cache hit never re-times anything, so tuned runs are
  deterministic and CI (which commits the cache) never sweeps.

``ops``'s entry points opt in with ``tiles="auto"``; the static
heuristics stay the default, so every existing oracle test is untouched.
Any *legal* tile choice preserves the kernels' numerics contract: MX
group scales are a property of the data layout (groups of 32 along K),
not of the tile grid, so on exact-arithmetic operands
(``tests/fuzz.exact_mx_operands``) every candidate — and the
double-buffered layout — is bitwise equal to the static default.  For
the block-scaled GEMM the scale grid IS the config's block size, so its
candidates only *subdivide* the scale blocks (the kernel reads the same
scalar scale per compute tile — see ``blockscale_gemm_pallas``'s
``scale_block_*`` parameters).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time

import numpy as np

__all__ = ["TuneResult", "autotune", "peek", "clear_memo", "cache_dir",
           "time_us_median", "gemm_tile_candidates",
           "attention_tile_candidates", "gemm_packed_tiles",
           "blockscale_tiles", "attention_tiles"]

CACHE_VERSION = 1

# per-grid-step VMEM working-set budget for swept GEMM tiles (bytes);
# ~half the 16 MiB/core so the pipelined next tile fits alongside
VMEM_BUDGET = 8 * 2 ** 20

_MEMO: dict = {}


# ------------------------------------------------------------ cache -------

def cache_dir() -> str:
    """Resolution order: ``REPRO_TUNE_DIR`` env var → the repo's
    committed ``benchmarks/baselines/tune/`` (when running from a
    checkout) → ``~/.cache/repro/tune``."""
    env = os.environ.get("REPRO_TUNE_DIR")
    if env:
        return env
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    cand = os.path.join(repo, "benchmarks", "baselines", "tune")
    if os.path.isdir(os.path.join(repo, "benchmarks")):
        return cand
    return os.path.join(os.path.expanduser("~"), ".cache", "repro", "tune")


def _cache_path(kernel: str, cdir=None) -> str:
    return os.path.join(cdir or cache_dir(), f"{kernel}.json")


def _load(kernel: str, cdir=None) -> dict:
    path = _cache_path(kernel, cdir)
    memo_key = ("file", path)
    if memo_key in _MEMO:
        return _MEMO[memo_key]
    data = {"version": CACHE_VERSION, "entries": {}}
    try:
        with open(path) as f:
            raw = json.load(f)
        if raw.get("version") == CACHE_VERSION:
            data = raw
    except (OSError, ValueError):
        pass
    _MEMO[memo_key] = data
    return data


def _store(kernel: str, key: str, entry: dict, cdir=None) -> None:
    data = _load(kernel, cdir)
    data["entries"][key] = entry
    path = _cache_path(kernel, cdir)
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass                     # read-only checkout: memo still serves


def clear_memo() -> None:
    """Drop the in-process cache memo (tests; after editing cache files)."""
    _MEMO.clear()


# ------------------------------------------------------------ timing ------

def time_us_median(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-clock microseconds of ``fn(*args)``.

    Every iteration blocks on the result (``jax.block_until_ready``) —
    including the warmups, so compilation and the async dispatch queue
    are fully drained before the first timed sample — and the median of
    per-iteration times is returned rather than the mean, so a single
    scheduler hiccup cannot skew the number (the timing convention
    shared with ``benchmarks/run.py`` — EXPERIMENTS.md §Conventions).
    """
    import jax
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


# ------------------------------------------------------------ core --------

@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of a tile lookup: the chosen ``tiles`` tuple, the median
    microseconds it measured (None on a pure cache hit recorded by an
    older sweep without timing, or a stubbed bench) and the ``source``
    — 'cache' (no timing ran), 'swept' (this call measured every
    candidate) or 'default' (no candidates; the static heuristic)."""
    tiles: tuple
    us: "float | None"
    source: str


def peek(kernel: str, key: str, *, cache_dir=None) -> "TuneResult | None":
    """Cached entry for ``key``, or None — never sweeps."""
    entry = _load(kernel, cache_dir)["entries"].get(key)
    if entry is None:
        return None
    return TuneResult(tuple(entry["tiles"]), entry.get("us"), "cache")


def autotune(kernel: str, key: str, candidates, bench_fn, *,
             iters: int = 3, warmup: int = 1,
             cache_dir=None) -> TuneResult:
    """Pick the fastest tile tuple for ``key`` among ``candidates``.

    ``bench_fn(tiles) -> float`` returns ONE wall-clock measurement in
    microseconds (injected so tests can stub it); the autotuner takes
    the median of ``iters`` calls after ``warmup`` discarded ones.  The
    winner is persisted under ``<cache_dir>/<kernel>.json`` keyed by
    ``key``; a later call with the same key returns it without invoking
    ``bench_fn`` at all (cache-hit determinism).  A candidate whose
    bench raises is skipped (scored +inf); if every candidate fails the
    first candidate is returned unpersisted with source 'default'.
    """
    candidates = [tuple(c) for c in candidates]
    assert candidates, kernel
    hit = peek(kernel, key, cache_dir=cache_dir)
    if hit is not None and tuple(hit.tiles) in candidates:
        return hit
    best, best_us = None, math.inf
    for cand in candidates:
        try:
            for _ in range(max(warmup, 0)):
                bench_fn(cand)
            us = float(np.median([bench_fn(cand)
                                  for _ in range(max(iters, 1))]))
        except Exception:
            continue
        if us < best_us:
            best, best_us = cand, us
    if best is None:
        return TuneResult(candidates[0], None, "default")
    _store(kernel, key, {"tiles": list(best), "us": best_us}, cache_dir)
    return TuneResult(best, best_us, "swept")


# ------------------------------------------------- candidate spaces -------

def _ceil_mult(dim: int, unit: int) -> int:
    return max(unit, dim + (-dim) % unit)


def _ladder(cap: int, units) -> list:
    """Ascending multiples of each unit up to ``cap`` (deduped)."""
    out = set()
    for u in units:
        b = u
        while b <= cap:
            out.add(b)
            b *= 2
    return sorted(out)


def gemm_tile_candidates(m: int, n: int, k: int, *, lane_units=(128,),
                         group: int = 1,
                         vmem_bytes_fn=None) -> "list[tuple]":
    """Legal (block_m, block_n, block_k) candidates for a packed
    (M, K) × (K, N) GEMM sweep.

    Floors (the ``mx_packed_blocks`` legality rules, enumerated instead
    of fixed): block_m is a sublane 8-multiple, block_n a lane
    128-multiple, block_k a multiple of lcm(128, group, *lane_units) —
    so every candidate's packed byte run is a legal lane tile for every
    codec involved.  No tile exceeds the minimally padded problem
    (padding cost is bounded by one tile), and ``vmem_bytes_fn(tiles)``
    (when given) prunes candidates whose per-step working set exceeds
    ``VMEM_BUDGET``.
    """
    ku = 128 * group // math.gcd(128, group)
    for u in lane_units:
        ku = ku * u // math.gcd(ku, u)
    cands = []
    for bm in _ladder(min(256, _ceil_mult(m, 8)), (8,)):
        for bn in _ladder(min(512, _ceil_mult(n, 128)), (128,)):
            for bk in _ladder(min(4 * ku, _ceil_mult(k, ku)), (ku,)):
                t = (bm, bn, bk)
                if vmem_bytes_fn and vmem_bytes_fn(t) > VMEM_BUDGET:
                    continue
                cands.append(t)
    return cands


def attention_tile_candidates(s: int, t: int, *, q_floor: int = 8,
                              k_floor: int = 8) -> "list[tuple]":
    """Legal (block_q, block_k) candidates for an S × T attention sweep:
    powers of two ≤ 128 that divide the length *exactly* (the attention
    kernels assert divisibility — masks are positional, so padding would
    need an extra in-kernel mask), bounded below by the sublane floor
    (8; the decode q axis may fall to ``q_floor=1`` — S=1 steady-state
    decode, interp/CPU-only below 8, the §12 convention)."""
    def picks(n, floor):
        return [b for b in (128, 64, 32, 16, 8, 4, 2, 1)
                if b >= floor and n % b == 0]

    return [(bq, bk) for bq in picks(s, q_floor) for bk in picks(t, k_floor)]


# ------------------------------------------------- kernel frontends -------
# Each frontend builds the cache key, the legal candidate space and a
# synthetic-operand bench closure for one kernel family, and funnels
# through ``autotune``.  Synthetic operands (random payload bytes /
# carrier values at the caller's real shapes) keep the sweep callable
# from inside a jit trace: timing runs on concrete arrays regardless of
# whether the caller's operands are tracers.

def _backend_tag(impl: str) -> str:
    import jax
    mode = "interp" if impl == "pallas_interpret" else "compiled"
    return f"{jax.default_backend()}-{mode}"


def _pad_to(x: int, b: int) -> int:
    return x + (-x) % b


def gemm_packed_tiles(m: int, n: int, k: int, mx_a, mx_b, *,
                      impl: str = "pallas", sweep: bool = True,
                      bench_fn=None, cache_dir=None,
                      iters: int = 3) -> "tuple[tuple, bool, TuneResult]":
    """Tuned (block_m, block_n, block_k) + double-buffer flag for
    ``mx_gemm_packed_pallas`` on an (M, K) × (K, N) problem.

    Returns ``((bm, bn, bk), double_buffer, result)``.  The swept
    layout axis is the K-loop streaming schedule: each tile shape is a
    candidate twice, ``(bm, bn, bk, 0)`` grid-pipelined and
    ``(bm, bn, bk, 1)`` double-buffered manual DMA (only when the
    problem has ≥ 2 K-tiles — a single-tile K-loop has nothing to
    overlap).  With ``sweep=False`` a cache miss falls back to the
    static heuristic (``ops.mx_packed_blocks``) instead of timing —
    the CPU-CI mode, where only the committed cache ever answers.
    """
    from ..core.formats import get_mx_format
    from .codec import get_codec

    mx_a = get_mx_format(mx_a)
    mx_b = get_mx_format(mx_b) if mx_b is not None else mx_a
    ca, cb = get_codec(mx_a), get_codec(mx_b)
    g = mx_a.group

    def vmem(tl):
        bm, bn, bk = tl[:3]
        return (bm * ca.packed_cols(bk) + bn * cb.packed_cols(bk)
                + (bm + bn) * bk                    # u8 scale codes
                + 2 * bm * bn * 4)                  # acc + out
    base = gemm_tile_candidates(m, n, k, group=g,
                                lane_units=(ca.lane_unit, cb.lane_unit),
                                vmem_bytes_fn=vmem)
    cands = []
    for bm, bn, bk in base:
        cands.append((bm, bn, bk, 0))
        if _pad_to(k, bk) // bk >= 2:
            cands.append((bm, bn, bk, 1))
    key = (f"m{m}n{n}k{k}|{mx_a.name}+{mx_b.name}|{_backend_tag(impl)}")
    kernel = "mx_gemm_packed"
    hit = peek(kernel, key, cache_dir=cache_dir)
    if hit is not None and tuple(hit.tiles) in cands:
        return tuple(hit.tiles[:3]), bool(hit.tiles[3]), hit
    if not sweep and bench_fn is None:
        from . import ops
        return ops.mx_packed_blocks(m, n, g, ca, cb), False, TuneResult(
            ops.mx_packed_blocks(m, n, g, ca, cb) + (0,), None, "default")

    if bench_fn is None:
        from .blockscale_gemm import mx_gemm_packed_pallas
        rng = np.random.default_rng(0)
        interp = impl == "pallas_interpret"

        def bench_fn(tl):
            import jax.numpy as jnp
            bm, bn, bk, db = tl
            mp, np_, kp = _pad_to(m, bm), _pad_to(n, bn), _pad_to(k, bk)
            ap = jnp.asarray(rng.integers(
                0, 256, (mp, ca.packed_cols(kp)), dtype=np.uint8))
            bp = jnp.asarray(rng.integers(
                0, 256, (np_, cb.packed_cols(kp)), dtype=np.uint8))
            s_a = jnp.full((mp, kp), 127, jnp.uint8)
            s_b = jnp.full((np_, kp), 127, jnp.uint8)
            return time_us_median(
                lambda: mx_gemm_packed_pallas(
                    ap, bp, s_a, s_b, mx_a=mx_a, mx_b=mx_b,
                    block_m=bm, block_n=bn, block_k=bk,
                    double_buffer=bool(db), interpret=interp),
                warmup=0, iters=1)

    res = autotune(kernel, key, cands, bench_fn, iters=iters,
                   cache_dir=cache_dir)
    return tuple(res.tiles[:3]), bool(res.tiles[3]), res


def blockscale_tiles(m: int, n: int, k: int, scale_blocks, q_dtype_a,
                     q_dtype_b, *, impl: str = "pallas", sweep: bool = True,
                     bench_fn=None, cache_dir=None,
                     iters: int = 3) -> "tuple[tuple, TuneResult]":
    """Tuned compute tiles for ``blockscale_gemm_pallas`` under a FIXED
    scale grid ``scale_blocks = (sm, sn, sk)``.

    The scale grid is the numerics contract (one scale per (sm × sk) /
    (sk × sn) block — DESIGN.md §3), so candidates only *subdivide* it:
    bm | sm (8-multiples), bn | sn and bk | sk (128-multiples).  Every
    candidate reads the same scalar scale per compute tile, so the math
    is unchanged (identical on exact operands; K-split order aside).
    """
    import jax.numpy as jnp
    sm, sn, sk = scale_blocks

    def divs(s, unit):
        return [b for b in _ladder(s, (unit,)) if s % b == 0]

    cands = [(bm, bn, bk) for bm in divs(sm, 8) for bn in divs(sn, 128)
             for bk in divs(sk, 128)]
    key = (f"m{m}n{n}k{k}|s{sm}x{sn}x{sk}|{jnp.dtype(q_dtype_a).name}"
           f"+{jnp.dtype(q_dtype_b).name}|{_backend_tag(impl)}")
    kernel = "blockscale_gemm"
    hit = peek(kernel, key, cache_dir=cache_dir)
    if hit is not None and tuple(hit.tiles) in cands:
        return tuple(hit.tiles), hit
    if not sweep and bench_fn is None:
        return (sm, sn, sk), TuneResult((sm, sn, sk), None, "default")

    if bench_fn is None:
        from .blockscale_gemm import blockscale_gemm_pallas
        rng = np.random.default_rng(0)
        interp = impl == "pallas_interpret"
        mp, np_, kp = _pad_to(m, sm), _pad_to(n, sn), _pad_to(k, sk)
        a = jnp.asarray(rng.normal(0, 1, (mp, kp)), jnp.float32)
        b = jnp.asarray(rng.normal(0, 1, (kp, np_)), jnp.float32)
        sa = jnp.ones((mp // sm, kp // sk), jnp.float32)
        sb = jnp.ones((kp // sk, np_ // sn), jnp.float32)

        def bench_fn(tl):
            bm, bn, bk = tl
            return time_us_median(
                lambda: blockscale_gemm_pallas(
                    a, b, sa, sb, q_dtype_a=q_dtype_a, q_dtype_b=q_dtype_b,
                    block_m=bm, block_n=bn, block_k=bk,
                    scale_block_m=sm, scale_block_n=sn, scale_block_k=sk,
                    interpret=interp),
                warmup=0, iters=1)

    res = autotune(kernel, key, cands, bench_fn, iters=iters,
                   cache_dir=cache_dir)
    return tuple(res.tiles), res


def attention_tiles(kind: str, bh: int, s: int, t: int, hd: int, *,
                    fmt_k=None, fmt_v=None, causal: bool = True,
                    impl: str = "pallas", sweep: bool = True,
                    bench_fn=None, cache_dir=None,
                    iters: int = 3) -> "tuple[tuple, TuneResult]":
    """Tuned (block_q, block_k) for the flash/decode sweeps.

    ``kind`` ∈ {'flash', 'mx_flash', 'decode', 'mx_decode'} — the four
    §11/§12 kernels.  Candidates divide S and T exactly (q floor 8 for
    the train/prefill kernels, 1 for decode — §12's short-q convention);
    the packed variants key on the KV formats, whose codec only affects
    byte traffic, not legality of (bq, bk).  Falls back to the static
    heuristic on a cache miss when ``sweep=False``.
    """
    assert kind in ("flash", "mx_flash", "decode", "mx_decode"), kind
    from ..core.formats import get_mx_format
    decode = kind.endswith("decode")
    q_floor = 1 if decode else 8
    cands = attention_tile_candidates(s, t, q_floor=q_floor)
    fk = get_mx_format(fmt_k).name if fmt_k is not None else "carrier"
    fv = (get_mx_format(fmt_v).name if fmt_v is not None else fk)
    key = (f"bh{bh}s{s}t{t}hd{hd}|{fk}+{fv}|causal={int(causal)}"
           f"|{_backend_tag(impl)}")
    kernel = f"{kind}_attention"
    hit = peek(kernel, key, cache_dir=cache_dir)
    if hit is not None and tuple(hit.tiles) in cands:
        return tuple(hit.tiles), hit
    if not sweep and bench_fn is None:
        from . import ops
        static = (ops.decode_attention_blocks(s, t) if decode
                  else (ops.attention_blocks(s, t) or (8, 8)))
        return static, TuneResult(static, None, "default")

    if bench_fn is None:
        bench_fn = _attention_bench(kind, bh, s, t, hd, fmt_k, fmt_v,
                                    causal, impl)
    res = autotune(kernel, key, cands, bench_fn, iters=iters,
                   cache_dir=cache_dir)
    return tuple(res.tiles), res


def _attention_bench(kind, bh, s, t, hd, fmt_k, fmt_v, causal, impl):
    """Synthetic-operand bench closure for one attention kernel family."""
    import jax.numpy as jnp
    from ..core.formats import get_mx_format
    from .codec import get_codec

    rng = np.random.default_rng(0)
    interp = impl == "pallas_interpret"
    q = jnp.asarray(rng.normal(0, 1, (bh, s, hd)), jnp.float32)
    if kind in ("mx_flash", "mx_decode"):
        mx_k = get_mx_format(fmt_k)
        mx_v = get_mx_format(fmt_v) if fmt_v is not None else mx_k
        ck, cv = get_codec(mx_k), get_codec(mx_v)
        kp = jnp.asarray(rng.integers(
            0, 256, (bh, t, ck.packed_cols(hd)), dtype=np.uint8))
        vp = jnp.asarray(rng.integers(
            0, 256, (bh, t, cv.packed_cols(hd)), dtype=np.uint8))
        s8 = jnp.full((bh, t, hd // mx_k.group), 127, jnp.uint8)
        if kind == "mx_flash":
            from .flash_attention import mx_flash_attention_pallas

            def run(bq, bk):
                return mx_flash_attention_pallas(
                    q, kp, s8, vp, s8, mx_k=mx_k, mx_v=mx_v, causal=causal,
                    block_q=bq, block_k=bk, interpret=interp)
        else:
            from .decode_attention import mx_decode_attention_pallas
            lens = jnp.zeros((bh,), jnp.int32)

            def run(bq, bk):
                return mx_decode_attention_pallas(
                    q, kp, s8, vp, s8, lens, mx_k=mx_k, mx_v=mx_v,
                    block_q=bq, block_k=bk, interpret=interp)
    else:
        k = jnp.asarray(rng.normal(0, 1, (bh, t, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (bh, t, hd)), jnp.float32)
        if kind == "flash":
            from .flash_attention import flash_attention_pallas

            def run(bq, bk):
                return flash_attention_pallas(
                    q, k, v, causal=causal, block_q=bq, block_k=bk,
                    interpret=interp)
        else:
            from .decode_attention import decode_attention_pallas
            lens = jnp.zeros((bh,), jnp.int32)

            def run(bq, bk):
                return decode_attention_pallas(
                    q, k, v, lens, block_q=bq, block_k=bk, interpret=interp)

    def bench_fn(tl):
        bq, bk = tl
        return time_us_median(lambda: run(bq, bk), warmup=0, iters=1)

    return bench_fn
