"""Flash attention — Pallas TPU kernel (online-softmax, O(S) memory).

The attention analogue of the ExSdotp rule: logits and the softmax
accumulator live in f32 VMEM scratch at full precision for the whole KV
sweep (never materialized to HBM), with a single rounding to the carrier
dtype when the output block retires. This removes the O(S^2) score
materialization that dominates the prefill_32k memory roofline term
(EXPERIMENTS.md §Roofline).

Layout: q/k/v [BH, S, hd]; grid (BH, S/bq, T/bk), KV innermost
('arbitrary'); running (m, l, acc) in VMEM scratch. Causal masking by
absolute position; fully-masked future blocks still execute (structural
zero — acceptable at dry-run level; a carry-skip via
pltpu.CompilerParams is the known next step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, scale: float, block_q: int, block_k: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                    # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        iq = pl.program_id(1)
        rows = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0)
        cols = kk * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kk == pl.num_programs(2) - 1)
    def _write():
        # single rounding into the carrier dtype (the ExSdotp rule)
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q [BH, S, hd], k/v [BH, T, hd] -> [BH, S, hd] (same dtype as q)."""
    bh, s, hd = q.shape
    t = k.shape[1]
    assert s % block_q == 0 and t % block_k == 0, ((s, t),
                                                   (block_q, block_k))
    scale = hd ** -0.5
    kern = functools.partial(_kernel, causal=causal, scale=scale,
                             block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=(bh, s // block_q, t // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, kk: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, kk: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),      # running max
            pltpu.VMEM((block_q, 1), jnp.float32),      # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),     # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
