"""Flash attention — Pallas TPU kernels (online-softmax, O(S) memory).

The attention analogue of the ExSdotp rule: logits and the softmax
accumulator live in f32 VMEM scratch at full precision for the whole KV
sweep (never materialized to HBM), with a single rounding to the carrier
dtype when the output block retires. This removes the O(S^2) score
materialization that dominates the prefill_32k memory roofline term
(EXPERIMENTS.md §Roofline).

Two kernels share one online-softmax core (``_sweep_body``):

* ``flash_attention_pallas`` — carrier-precision q/k/v (the original).
* ``mx_flash_attention_pallas`` — the KV sweep quantized (DESIGN.md
  §11): k/v enter the kernel as *packed* codec payloads (uint8 lanes at
  ``width/8`` bytes per element) plus E8M0 group-scale codes over the
  head dimension, and are unpacked + decoded in-register
  (``codec.decode_lanes(...) * e8m0_decode(...)``) right before the
  q·kᵀ and p·v dots — the same fold point as ``mx_gemm_packed_pallas``.
  E8M0 scales are exact powers of two, so folding the dequant into the
  decoded operands is bit-identical to rescaling partial products at
  accumulator granularity; the logits and the (m, l, acc) state never
  see narrow precision.

Layout: q [BH, S, hd], k/v [BH, T, hd] (packed: [BH, T, hd·w/8] payload
+ [BH, T, hd/group] E8M0 codes); grid (BH, S/bq, T/bk), KV innermost
('arbitrary'); running (m, l, acc) in VMEM scratch. Causal masking by
absolute position.

Carry-skip (``skip_masked``, default on): a causal tile whose every
column index exceeds its every row index (``kk·bk ≥ (iq+1)·bq``) is a
structural zero — its masked logits contribute ``exp(-1e30 - m) = 0``
to l/acc and never move the running max — so the whole exp/dot body is
skipped under ``pl.when``.  Output is bit-identical with the skip on or
off for finite operands; causal prefill stops paying ~half the sweep.

Compiled-TPU lane legality: the packed payload's last axis is
``hd·width/8`` bytes, which must be a 128-multiple on real hardware
(``codec.lane_unit`` — satisfied by hd=128 FP8; other combinations pad
the head axis at the layer above).  Interp/CPU CI masks violations —
the same convention as ``ops.blockscale_blocks``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.formats import e8m0_decode
from .codec import get_codec
from ._compat import CompilerParams

__all__ = ["flash_attention_pallas", "mx_flash_attention_pallas"]

NEG_INF = -1e30


def _sweep_body(q, k, v, m_ref, l_ref, acc_ref, *, iq, kk, causal, scale,
                block_q, block_k, base=None):
    """One KV tile of the online-softmax recurrence (f32 throughout).

    ``q [bq, hd]``, ``k/v [bk, hd]`` are already-decoded f32 operands —
    all kernels funnel through here, so the carry-skip and the MX
    variant cannot drift from the carrier-precision kernel's math.
    ``iq``/``kk`` are the grid coordinates, read once at the kernel's
    top level (``pl.program_id`` must not be bound inside a ``pl.when``
    body — the carry-skip wraps this whole function in one).

    ``base`` (decode kernels — DESIGN.md §12) is a per-sequence scalar
    offsetting q's absolute positions: q row ``i`` sits at cache slot
    ``base + i``, so the causal mask becomes ``col <= base + row``.
    ``base=None`` is the train/prefill case (identical to ``base=0``).
    """
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        rows = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0)
        if base is not None:
            rows = rows + base
        cols = kk * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols <= rows, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _kernel(q_ref, *refs, load_kv, causal, scale, block_q, block_k,
            skip_masked, debug_visited):
    """Shared kernel shell: init / carry-skip / sweep / retire.

    ``load_kv(refs)`` returns ``(loader, base, rest)`` — the only point
    the carrier, packed, and decode variants differ.  ``loader(kk,
    limit)`` yields the decoded f32 (k, v) tiles for KV-tile ``kk``
    (zeroing key slots at index >= ``limit`` when one is given — the
    decode kernels' structural exclusion of garbage cache slots beyond
    the live length, so stale poison in freed pages can't leak through
    ``0·NaN``).  ``base`` (None for train/prefill) is the per-sequence
    absolute-position offset; with it, q's S rows cover cache slots
    ``base..base+S-1`` and the live KV prefix is ``limit = base + S``.
    """
    loader, base, refs = load_kv(refs)
    if debug_visited:
        o_ref, vis_ref = refs[0], refs[1]
        m_ref, l_ref, acc_ref = refs[2:]
    else:
        o_ref, vis_ref = refs[0], None
        m_ref, l_ref, acc_ref = refs[1:]
    iq, kk = pl.program_id(1), pl.program_id(2)
    limit = None if base is None else base + pl.num_programs(1) * block_q

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if vis_ref is not None:
        vis_ref[0, 0, 0] = jnp.int32(0)

    def _update():
        q = q_ref[0].astype(jnp.float32)                # [bq, hd]
        k, v = loader(kk, limit)
        _sweep_body(q, k, v, m_ref, l_ref, acc_ref,
                    iq=iq, kk=kk, causal=causal, scale=scale,
                    block_q=block_q, block_k=block_k, base=base)
        if vis_ref is not None:
            vis_ref[0, 0, 0] = jnp.int32(1)

    if causal and skip_masked:
        # carry-skip: the tile is live iff its smallest column index can
        # reach its largest row index (kk·bk <= base + iq·bq + bq - 1);
        # otherwise every logit is the structural-zero NEG_INF and the
        # update is exactly a no-op — skip the exp/dot work entirely.
        # With a dynamic ``base`` this doubles as the page-skip: tiles
        # beyond a sequence's live length never execute.  Tile kk=0 is
        # always live (base >= 0), so (m, l) never retire all-masked.
        live = kk * block_k < (iq + 1) * block_q + (
            0 if base is None else base)

        @pl.when(live)
        def _live():
            _update()
    else:
        _update()

    @pl.when(kk == pl.num_programs(2) - 1)
    def _write():
        # single rounding into the carrier dtype (the ExSdotp rule)
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _call(kern, q, operands, operand_specs, *, block_q, block_k, t,
          debug_visited, interpret):
    bh, s, hd = q.shape
    grid = (bh, s // block_q, t // block_k)
    out_shape = [jax.ShapeDtypeStruct((bh, s, hd), q.dtype)]
    out_specs = [pl.BlockSpec((1, block_q, hd), lambda b, i, kk: (b, i, 0))]
    if debug_visited:
        out_shape.append(jax.ShapeDtypeStruct(grid, jnp.int32))
        out_specs.append(
            pl.BlockSpec((1, 1, 1), lambda b, i, kk: (b, i, kk)))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block_q, hd), lambda b, i, kk: (b, i, 0)),
                  *operand_specs],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),      # running max
            pltpu.VMEM((block_q, 1), jnp.float32),      # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),     # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, *operands)
    return tuple(out) if debug_visited else out[0]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "skip_masked",
                     "debug_visited", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           skip_masked: bool = True,
                           debug_visited: bool = False,
                           interpret: bool = False):
    """q [BH, S, hd], k/v [BH, T, hd] -> [BH, S, hd] (same dtype as q).

    The carrier-precision online-softmax sweep (DESIGN.md §11).
    ``skip_masked`` enables the causal carry-skip (bit-identical output
    for finite operands).  ``debug_visited=True`` additionally returns
    an int32 [BH, S/bq, T/bk] grid marking which tiles executed the
    sweep body — the interpret-mode hook for the masked-tile tests.

    Tile-legality contract (DESIGN.md §11/§14): ``block_q`` must divide
    S and ``block_k`` divide T *exactly* — the mask is positional, so
    this kernel asserts rather than pads; ``ops.attention_blocks`` (or
    the §14 autotuner, whose candidates divide by construction) picks
    legal tiles.  On compiled TPU ``block_q`` is a sublane 8-multiple
    and hd a lane 128-multiple (masked on CPU CI).
    """
    bh, s, hd = q.shape
    t = k.shape[1]
    assert s % block_q == 0 and t % block_k == 0, ((s, t),
                                                   (block_q, block_k))

    def load_kv(refs):
        k_ref, v_ref = refs[0], refs[1]

        def loader(kk, limit):
            return (k_ref[0].astype(jnp.float32),
                    v_ref[0].astype(jnp.float32))

        return loader, None, refs[2:]

    kern = functools.partial(
        _kernel, load_kv=load_kv, causal=causal, scale=hd ** -0.5,
        block_q=block_q, block_k=block_k, skip_masked=skip_masked,
        debug_visited=debug_visited)
    specs = [pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0)),
             pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0))]
    return _call(kern, q, (k, v), specs, block_q=block_q, block_k=block_k,
                 t=t, debug_visited=debug_visited, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("mx_k", "mx_v", "causal", "block_q", "block_k",
                     "skip_masked", "debug_visited", "interpret"))
def mx_flash_attention_pallas(q, kp, ks8, vp, vs8, *, mx_k, mx_v=None,
                              causal: bool = True, block_q: int = 128,
                              block_k: int = 128, skip_masked: bool = True,
                              debug_visited: bool = False,
                              interpret: bool = False):
    """Flash attention over *packed* MX KV (DESIGN.md §11).

    ``q [BH, S, hd]`` carrier precision; ``(kp, ks8)`` / ``(vp, vs8)``
    are ``ops.mx_quantize(k/v, mx, packed=True)``: payload
    ``[BH, T, hd·w/8]`` uint8 and E8M0 codes ``[BH, T, hd/group]`` —
    group scales run along the head dimension (the contraction axis of
    the q·kᵀ dot; for p·v the pow2 fold is per output column, equally
    exact).  Tiles stream packed from HBM and decode in-register; a
    0xFF scale code (non-finite group) decodes NaN and poisons exactly
    the rows that attend to it.

    Bit-exact vs ``ref.mx_flash_attention_ref`` on exact-arithmetic
    operands (``tests/fuzz.exact_attention_operands``) — the same bar
    every codec kernel meets.

    Tile-legality contract (DESIGN.md §11/§14): ``block_q`` | S and
    ``block_k`` | T exactly (positional mask — assert, don't pad), hd a
    whole number of groups; on compiled TPU ``block_q`` is a sublane
    8-multiple and the packed hd byte run a 128-multiple lane tile
    (``ops.mx_quantize_kv`` guarantees it for hd % group == 0).  Any
    legal tile choice is bitwise-equivalent — the §14 autotune axis.
    """
    from ..core.formats import get_mx_format
    mx_k = get_mx_format(mx_k)
    mx_v = mx_k if mx_v is None else get_mx_format(mx_v)
    ck, cv = get_codec(mx_k), get_codec(mx_v)
    g = mx_k.group
    assert mx_v.group == g, (mx_k.name, mx_v.name)
    bh, s, hd = q.shape
    t = kp.shape[1]
    assert s % block_q == 0 and t % block_k == 0, ((s, t),
                                                   (block_q, block_k))
    assert hd % g == 0, (hd, g)
    assert kp.shape == (bh, t, ck.packed_cols(hd)), (kp.shape, (bh, t, hd))
    assert vp.shape == (bh, t, cv.packed_cols(hd)), (vp.shape, (bh, t, hd))
    assert ks8.shape == vs8.shape == (bh, t, hd // g), (ks8.shape, vs8.shape)
    # scale codes enter the kernel at element resolution (compact
    # [.., hd/32] grids are lane-illegal on compiled TPU — the §8 rule,
    # one u8 per element; the repeat is exact and nearly free vs the
    # f32-wide value path it replaces)
    ks8e = jnp.repeat(ks8, g, axis=-1)
    vs8e = jnp.repeat(vs8, g, axis=-1)

    def load_kv(refs):
        kp_ref, ks_ref, vp_ref, vs_ref = refs[:4]

        def loader(kk, limit):
            return (ck.decode_lanes(kp_ref[0]) * e8m0_decode(ks_ref[0]),
                    cv.decode_lanes(vp_ref[0]) * e8m0_decode(vs_ref[0]))

        return loader, None, refs[4:]

    kern = functools.partial(
        _kernel, load_kv=load_kv, causal=causal, scale=hd ** -0.5,
        block_q=block_q, block_k=block_k, skip_masked=skip_masked,
        debug_visited=debug_visited)
    pk, pv = ck.packed_cols(hd), cv.packed_cols(hd)
    specs = [pl.BlockSpec((1, block_k, pk), lambda b, i, kk: (b, kk, 0)),
             pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0)),
             pl.BlockSpec((1, block_k, pv), lambda b, i, kk: (b, kk, 0)),
             pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0))]
    return _call(kern, q, (kp, ks8e, vp, vs8e), specs, block_q=block_q,
                 block_k=block_k, t=t, debug_visited=debug_visited,
                 interpret=interpret)
