"""Pure-jnp oracles for every Pallas kernel (bit-faithful semantics).

``mx_flash_attention_ref`` is the one numpy-carried oracle: it leans on
the numpy format mirrors (``mx_quantize_np``/``mx_dequantize_np``) so
the attention test harness has a reference with no JAX ops at all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import formats as F
from ..core.formats import get_mx_format, quantize
from ..core.scaling import expand_group_scales

__all__ = ["exsdotp_gemm_ref", "quant_blockwise_ref", "blockscale_gemm_ref",
           "mx_quant_ref", "mx_gemm_ref", "flash_attention_ref",
           "mx_flash_attention_ref", "decode_attention_ref",
           "mx_decode_attention_ref", "compressed_mean_mx_ref",
           "mx_dispatch_wire_ref"]


def exsdotp_gemm_ref(a: jax.Array, b: jax.Array, scale=1.0,
                     *, out_dtype=jnp.float32) -> jax.Array:
    """Expanding GEMM oracle: upcast, fp32 accumulate, scale, single downcast.

    Matches the kernel exactly when the fp32 accumulation itself is exact
    (e.g. integer-valued inputs); otherwise to within fp32 summation-order
    rounding (tested with tight tolerances).
    """
    acc = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return (acc * jnp.float32(scale)).astype(out_dtype)


def quant_blockwise_ref(x: jax.Array, *, q_dtype, block_m=128, block_n=128,
                        margin=1.0):
    m, n = x.shape
    gm, gn = m // block_m, n // block_n
    xb = x.astype(jnp.float32).reshape(gm, block_m, gn, block_n)
    amax = jnp.max(jnp.abs(xb), axis=(1, 3))
    max_normal = float(jnp.finfo(q_dtype).max)
    # non-finite amax -> scale 1: poison propagates instead of zeroing
    s = jnp.where((amax > 0) & jnp.isfinite(amax),
                  amax / (max_normal * margin), 1.0)
    q = (xb / s[:, None, :, None]).astype(q_dtype)
    return q.reshape(m, n), s


def blockscale_gemm_ref(a: jax.Array, b: jax.Array, sa: jax.Array,
                        sb: jax.Array, *, q_dtype_a, q_dtype_b,
                        block_m=128, block_n=128, block_k=128,
                        out_dtype=jnp.float32) -> jax.Array:
    """Oracle for the fused block-scaled GEMM (same math, pure jnp).

    Quantize each (row-tile × K-tile) of ``a`` (K-tile × col-tile of
    ``b``) with its own scale, dequantize, fp32-accumulate, round once.
    Bit-identical to the kernel whenever fp32 accumulation is exact.

    ``a``/``sa`` may carry leading batch dims (``a[..., M, K]``,
    ``sa[..., M/bm, K/bk]``): row tiles never cross them, and the
    contraction keeps native rank (no flatten — sharded leading dims
    survive under GSPMD).
    """
    *lead, m, k = a.shape
    _, n = b.shape
    gm, gk, gn = m // block_m, k // block_k, n // block_n

    def deq(x, s, br, bc, q_dtype):
        xb = x.astype(jnp.float32).reshape(
            *x.shape[:-2], x.shape[-2] // br, br, x.shape[-1] // bc, bc)
        st = s[..., :, None, :, None]
        q = (xb / st).astype(q_dtype).astype(jnp.float32)
        return (q * st).reshape(x.shape)

    assert (*lead, gm, gk) == sa.shape and (gk, gn) == sb.shape, (
        sa.shape, sb.shape)
    af = deq(a, sa.astype(jnp.float32), block_m, block_k, q_dtype_a)
    bf = deq(b, sb.astype(jnp.float32), block_k, block_n, q_dtype_b)
    acc = jnp.einsum("...mk,kn->...mn", af, bf,
                     preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


def mx_quant_ref(x: jax.Array, *, mx):
    """Oracle for the fused MX quantize kernel (same math, pure jnp).

    Per-(row × group-of-32-along-K) E8M0 scales + value-space element
    cast; returns ``(q[..., K] f32, s[..., K/group] f32)``.
    """
    from ..core.scaling import apply_group_scales, compute_group_scales
    mx = get_mx_format(mx)
    xf = x.astype(jnp.float32)
    s = compute_group_scales(xf, mx.group, mx.elem.max_normal)
    q = quantize(apply_group_scales(xf, s, mx.group, inverse=True), mx.elem)
    return q, s


def mx_gemm_ref(a: jax.Array, b: jax.Array, sa: jax.Array, sb: jax.Array,
                *, mx_a, mx_b=None, out_dtype=jnp.float32) -> jax.Array:
    """Oracle for the fused MX GEMM (same math, pure jnp).

    Quantize each 1×group strip of ``a`` along K (group × column strip of
    ``b``) with its own E8M0 scale, dequantize (exact — pow2 scales),
    fp32-accumulate, round once.  Bit-identical to the kernel whenever
    fp32 accumulation is exact.  ``a``/``sa`` may carry leading batch
    dims (``a[..., M, K]``, ``sa[..., M, K/g]``).
    """
    mx_a = get_mx_format(mx_a)
    mx_b = mx_a if mx_b is None else get_mx_format(mx_b)
    g = mx_a.group

    def deq_rows(x, s, fmt):  # groups along the last axis
        se = expand_group_scales(s.astype(jnp.float32), g).reshape(x.shape)
        return quantize(x.astype(jnp.float32) / se, fmt) * se

    af = deq_rows(a, sa, mx_a.elem)
    bf = deq_rows(b.T, sb.T, mx_b.elem).T  # b groups run along K, per column
    acc = jnp.einsum("...mk,kn->...mn", af, bf,
                     preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


def flash_attention_ref(q, k, v, *, causal=True):
    """q [BH,S,hd], k/v [BH,T,hd] — exact softmax attention oracle."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, tk = s.shape[-2:]
        mask = jnp.arange(tk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, lens, *, neg=-1e30):
    """Decode attention oracle (pure jnp — the serving xla branch).

    ``q [BH, S, hd]`` rows sit at absolute cache slots ``lens + i``
    against a cache ``k/v [BH, T, hd]`` whose live prefix is
    ``lens + S`` per sequence-head; garbage slots beyond it are zeroed
    *structurally* (both operands, before any dot) so stale non-finite
    trash in dead cache slots cannot reach live rows.  Mirrors the
    kernel's operation order — masked logits at ``-1e30`` (not -inf),
    row max, ``p = exp(s - m)``, one division by ``max(l, 1e-30)`` —
    so exact-arithmetic operands reproduce it bitwise.
    """
    bh, s, hd = q.shape
    t = k.shape[1]
    lens = jnp.asarray(lens, jnp.int32)
    cols = jnp.arange(t)[None, :]                      # [1, T]
    good = cols < (lens[:, None] + s)                  # [BH, T] live prefix
    kf = jnp.where(good[..., None], k.astype(jnp.float32), 0.0)
    vf = jnp.where(good[..., None], v.astype(jnp.float32), 0.0)
    scale = jnp.float32(hd ** -0.5)
    sc = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), kf) * scale
    rows = lens[:, None, None] + jnp.arange(s)[None, :, None]  # [BH, S, 1]
    sc = jnp.where(cols[:, None, :] <= rows, sc, jnp.float32(neg))
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bqk,bkd->bqd", p, vf)
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def mx_decode_attention_ref(q, k, v, lens, *, mx_k, mx_v=None):
    """Numpy oracle for the packed-cache decode attention kernel.

    Takes *high-precision* cache contents ``k/v [BH, T, hd]``,
    quantizes them with the numpy MX mirrors (per row × group-of-32
    along hd — exactly what ``ops.mx_quantize_kv`` stores in the page
    pool), and computes the base-offset masked attention of
    ``decode_attention_ref`` in pure numpy, mirroring the kernel's
    operation order (m → p → l → Σp·v → one division).

    Masked and garbage keys are excluded from the weighted sum
    *structurally* (the p·v products are zeroed, not merely weighted by
    an underflowed exp) — matching the kernel's carry/page-skip and
    garbage masking.  NaN-scale poison inside the *fully visible*
    region propagates identically in both; tests keep poison out of
    the partially-masked diagonal band (same §11 caveat as
    ``mx_flash_attention_ref``).  Returns ``[BH, S, hd]`` as q.dtype.
    """
    mx_k = get_mx_format(mx_k)
    mx_v = mx_k if mx_v is None else get_mx_format(mx_v)
    qf = np.asarray(q, np.float32)
    lens = np.asarray(lens, np.int32)
    bh, s, hd = qf.shape
    t = np.asarray(k).shape[1]
    kq, ks = F.mx_quantize_np(np.asarray(k, np.float32), mx_k)
    vq, vs = F.mx_quantize_np(np.asarray(v, np.float32), mx_v)
    kf = F.mx_dequantize_np(kq, ks, mx_k).astype(np.float32)
    vf = F.mx_dequantize_np(vq, vs, mx_v).astype(np.float32)
    cols = np.arange(t)[None, :]                       # [1, T]
    good = cols < (lens[:, None] + s)                  # [BH, T]
    kf = np.where(good[..., None], kf, np.float32(0))
    vf = np.where(good[..., None], vf, np.float32(0))
    scale = np.float32(hd ** -0.5)
    with np.errstate(invalid="ignore", over="ignore"):
        sc = np.einsum("bqd,bkd->bqk", qf, kf).astype(np.float32) * scale
        rows = lens[:, None, None] + np.arange(s)[None, :, None]
        valid = cols[:, None, :] <= rows               # [BH, S, T]
        sc = np.where(valid, sc, np.float32(-1e30))
        m = sc.max(axis=-1, keepdims=True)
        p = np.exp(sc - m)
        l = p.sum(axis=-1, keepdims=True, dtype=np.float32)
        pv = p[..., None] * vf[:, None, :, :]          # [BH, S, T, hd]
        pv = np.where(valid[..., None], pv, np.float32(0))
        acc = pv.sum(axis=-2, dtype=np.float32)
        out = acc / np.maximum(l, np.float32(1e-30))
    return out.astype(np.asarray(q).dtype)


def compressed_mean_mx_ref(grads, efs, *, mx):
    """Numpy oracle for the MX DP gradient wire (DESIGN.md §13).

    ``grads``/``efs`` are length-``n`` lists of same-shaped arrays, one
    per source replica.  Mirrors ``optim.grad_compress._leaf_mx``
    source by source: ``gc = g + e`` flattens, zero-pads to whole
    groups of ``mx.group``, quantizes with the numpy MX mirrors
    (E8M0 pow2 scales, NaN-scale poison for non-finite groups), and the
    mean of the *dequantized* streams — sliced back to the original
    shape — is what every receiver computes.  New error feedback is the
    local residual, reset to zero when non-finite (the wire's carried
    state must stay clean even on poisoned steps).

    Returns ``(mean, new_efs)``; pure numpy, f64 accumulation — exact
    whenever the jax path's chunked f32 accumulation is (the
    exact-arithmetic operand harness guarantees both).
    """
    mx = get_mx_format(mx)
    shape = np.asarray(grads[0]).shape
    size = int(np.prod(shape))
    kp = -(-size // mx.group) * mx.group
    deqs, new_efs = [], []
    with np.errstate(invalid="ignore", over="ignore"):
        for g, e in zip(grads, efs):
            gc = np.asarray(g, np.float32) + np.asarray(e, np.float32)
            fp = np.zeros(kp, np.float32)
            fp[:size] = gc.reshape(-1)
            q, s = F.mx_quantize_np(fp, mx)
            deq = F.mx_dequantize_np(q, s, mx).astype(np.float32)
            ne = (fp - deq)[:size].reshape(shape)
            if not np.all(np.isfinite(ne)):
                ne = np.zeros_like(ne)
            deqs.append(deq)
            new_efs.append(ne)
        mean = (np.sum(np.stack(deqs).astype(np.float64), axis=0)
                / len(grads)).astype(np.float32)
    return mean[:size].reshape(shape), new_efs


def mx_dispatch_wire_ref(x, *, mx):
    """Numpy oracle for one hop of the MoE packed dispatch wire: MX
    quantize over groups along the last axis (numpy mirrors, NaN-scale
    poison included), dequantize.  The all-to-all itself is a block
    permutation — bytes move, values don't — so the wire's value
    transform is exactly this roundtrip, and tests compare the on-mesh
    ``mx_dispatch_a2a`` output against the permuted roundtrip."""
    mx = get_mx_format(mx)
    with np.errstate(invalid="ignore", over="ignore"):
        q, s = F.mx_quantize_np(np.asarray(x, np.float32), mx)
        return F.mx_dequantize_np(q, s, mx).astype(np.float32)


def mx_flash_attention_ref(q, k, v, *, mx_k, mx_v=None, causal=True):
    """Numpy oracle for the MX-quantized KV flash attention kernel.

    Quantizes k/v per (row × group-along-hd) with the numpy MX mirrors
    (one E8M0 pow2 scale per 32 head-dim elements — lossless to undo),
    then computes f32 softmax attention mirroring the kernel's
    operation order: logits → row max → ``p = exp(s - m)`` →
    ``acc = Σ p·v`` → one division by ``max(l, 1e-30)``.  Bit-identical
    to ``mx_flash_attention_pallas`` whenever every f32 intermediate is
    exact (``tests/fuzz.exact_attention_operands`` constructs such
    operands: the per-block row max then equals the global max, so the
    online rescale factors are exactly 0 or 1).

    Masked (structurally-zero) keys are excluded from the weighted sum
    entirely — the ``p·v`` products are zeroed by the mask, not merely
    weighted by ``exp(-inf) = 0`` — matching the carry-skip kernel for
    every tile beyond the causal diagonal.  Poison (NaN-scale) groups
    in the *valid* region propagate identically in both; tests keep
    poison out of the partially-masked diagonal band, where the kernel
    necessarily still streams the masked columns of a live tile.

    Returns ``out [BH, S, hd]`` as ``q.dtype``; pure numpy throughout.
    """
    mx_k = get_mx_format(mx_k)
    mx_v = mx_k if mx_v is None else get_mx_format(mx_v)
    qf = np.asarray(q, np.float32)
    kq, ks = F.mx_quantize_np(np.asarray(k, np.float32), mx_k)
    vq, vs = F.mx_quantize_np(np.asarray(v, np.float32), mx_v)
    kf = F.mx_dequantize_np(kq, ks, mx_k).astype(np.float32)
    vf = F.mx_dequantize_np(vq, vs, mx_v).astype(np.float32)
    scale = np.float32(qf.shape[-1] ** -0.5)
    with np.errstate(invalid="ignore", over="ignore"):
        s = np.einsum("bqd,bkd->bqk", qf, kf).astype(np.float32) * scale
        sq, t = s.shape[-2], s.shape[-1]
        valid = None
        if causal:
            valid = np.arange(t)[None, :] <= np.arange(sq)[:, None]
            s = np.where(valid[None], s, -np.inf)
        m = s.max(axis=-1, keepdims=True)
        p = np.exp(s - m)
        l = p.sum(axis=-1, keepdims=True, dtype=np.float32)
        pv = p[..., None] * vf[:, None, :, :]            # [BH, S, T, hd]
        if valid is not None:
            pv = np.where(valid[None, :, :, None], pv, np.float32(0))
        acc = pv.sum(axis=-2, dtype=np.float32)
        out = acc / np.maximum(l, np.float32(1e-30))
    return out.astype(np.asarray(q).dtype)
