"""Pure-jnp oracles for every Pallas kernel (bit-faithful semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["exsdotp_gemm_ref", "quant_blockwise_ref"]


def exsdotp_gemm_ref(a: jax.Array, b: jax.Array, scale=1.0,
                     *, out_dtype=jnp.float32) -> jax.Array:
    """Expanding GEMM oracle: upcast, fp32 accumulate, scale, single downcast.

    Matches the kernel exactly when the fp32 accumulation itself is exact
    (e.g. integer-valued inputs); otherwise to within fp32 summation-order
    rounding (tested with tight tolerances).
    """
    acc = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return (acc * jnp.float32(scale)).astype(out_dtype)


def quant_blockwise_ref(x: jax.Array, *, q_dtype, block_m=128, block_n=128,
                        margin=1.0):
    m, n = x.shape
    gm, gn = m // block_m, n // block_n
    xb = x.astype(jnp.float32).reshape(gm, block_m, gn, block_n)
    amax = jnp.max(jnp.abs(xb), axis=(1, 3))
    max_normal = float(jnp.finfo(q_dtype).max)
    s = jnp.where(amax > 0, amax / (max_normal * margin), 1.0)
    q = (xb / s[:, None, :, None]).astype(q_dtype)
    return q.reshape(m, n), s


def flash_attention_ref(q, k, v, *, causal=True):
    """q [BH,S,hd], k/v [BH,T,hd] — exact softmax attention oracle."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, tk = s.shape[-2:]
        mask = jnp.arange(tk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
