"""Version shims for the Pallas TPU API surface.

The kernels target the current Pallas API; older jax releases spell some
names differently.  Centralizing the aliases here keeps every kernel file
on the modern spelling.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["CompilerParams"]

# jax < 0.5 calls it TPUCompilerParams; the kwargs are compatible.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
