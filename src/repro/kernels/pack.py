"""Packed sub-byte payload storage (DESIGN.md §9).

The MX emulation (§8) keeps FP6/FP4 element *values* in f32 carriers —
fine for numerics, useless as a memory/bandwidth model.  This module is
the honest storage layer: element bit patterns (``core.formats.encode``)
pack densely into uint8 lanes, so an FP4 tensor really is two elements
per byte and an FP6 tensor four elements in three bytes — the byte
counts the paper's 8-bit-end-to-end story (and `launch/hlo_analysis`'s
fractional element sizes) are calibrated against.

Bit layout is little-endian within a lane: element ``i``'s code occupies
bits ``[i*w, (i+1)*w)`` of the ``ceil(K*w/8)``-byte run, matching the
OCP MX convention of packing along the contiguous (K) axis.  numpy
oracles (``*_np``) define the layout; the jnp versions are bit-identical
and jit-safe (pure uint8 shifts/ors — XLA fuses them into the
surrounding quantize/dequantize).

FP4 lane (2 codes/byte)::

    byte0 = c0 | c1 << 4

FP6 lane (4 codes / 3 bytes)::

    byte0 = c0       | (c1 & 0x03) << 6
    byte1 = c1 >> 2  | (c2 & 0x0f) << 4
    byte2 = c2 >> 4  |  c3         << 2
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["pack_codes_np", "unpack_codes_np", "pack_codes", "unpack_codes",
           "pack4_np", "unpack4_np", "pack6_np", "unpack6_np",
           "pack4", "unpack4", "pack6", "unpack6", "packed_length"]


def packed_length(k: int, width: int) -> int:
    """Bytes holding ``k`` codes of ``width`` bits (k must tile whole
    bytes: k % 2 == 0 for FP4, k % 4 == 0 for FP6)."""
    assert (k * width) % 8 == 0, (k, width)
    return k * width // 8


# ------------------------------------------------------------- numpy ------

def pack4_np(codes: np.ndarray) -> np.ndarray:
    """[..., K] 4-bit codes -> [..., K/2] bytes (K even)."""
    c = np.asarray(codes).astype(np.uint8)
    assert c.shape[-1] % 2 == 0, c.shape
    return (c[..., 0::2] | (c[..., 1::2] << 4)).astype(np.uint8)


def unpack4_np(packed: np.ndarray) -> np.ndarray:
    """[..., B] bytes -> [..., 2B] 4-bit codes."""
    p = np.asarray(packed).astype(np.uint8)
    out = np.stack([p & 0x0F, p >> 4], axis=-1)
    return out.reshape(*p.shape[:-1], 2 * p.shape[-1])


def pack6_np(codes: np.ndarray) -> np.ndarray:
    """[..., K] 6-bit codes -> [..., 3K/4] bytes (K % 4 == 0)."""
    c = np.asarray(codes).astype(np.uint16)
    assert c.shape[-1] % 4 == 0, c.shape
    c0, c1, c2, c3 = (c[..., i::4] for i in range(4))
    b0 = c0 | (c1 & 0x03) << 6
    b1 = (c1 >> 2) | (c2 & 0x0F) << 4
    b2 = (c2 >> 4) | c3 << 2
    out = np.stack([b0, b1, b2], axis=-1)
    return out.reshape(*c.shape[:-1], 3 * c.shape[-1] // 4).astype(np.uint8)


def unpack6_np(packed: np.ndarray) -> np.ndarray:
    """[..., B] bytes (B % 3 == 0) -> [..., 4B/3] 6-bit codes."""
    p = np.asarray(packed).astype(np.uint16)
    assert p.shape[-1] % 3 == 0, p.shape
    b = p.reshape(*p.shape[:-1], p.shape[-1] // 3, 3)
    b0, b1, b2 = b[..., 0], b[..., 1], b[..., 2]
    c0 = b0 & 0x3F
    c1 = (b0 >> 6) | (b1 & 0x0F) << 2
    c2 = (b1 >> 4) | (b2 & 0x03) << 4
    c3 = b2 >> 2
    out = np.stack([c0, c1, c2, c3], axis=-1)
    return out.reshape(*p.shape[:-1], 4 * p.shape[-1] // 3).astype(np.uint8)


def pack_codes_np(codes: np.ndarray, width: int) -> np.ndarray:
    if width == 8:
        return np.asarray(codes).astype(np.uint8)
    return {4: pack4_np, 6: pack6_np}[width](codes)


def unpack_codes_np(packed: np.ndarray, width: int) -> np.ndarray:
    if width == 8:
        return np.asarray(packed).astype(np.uint8)
    return {4: unpack4_np, 6: unpack6_np}[width](packed)


# --------------------------------------------------------------- jnp ------

def pack4(codes: jax.Array) -> jax.Array:
    """jnp mirror of ``pack4_np`` (bit-identical)."""
    c = codes.astype(jnp.uint8)
    assert c.shape[-1] % 2 == 0, c.shape
    return c[..., 0::2] | (c[..., 1::2] << 4)


def unpack4(packed: jax.Array) -> jax.Array:
    p = packed.astype(jnp.uint8)
    out = jnp.stack([p & 0x0F, p >> 4], axis=-1)
    return out.reshape(*p.shape[:-1], 2 * p.shape[-1])


def pack6(codes: jax.Array) -> jax.Array:
    """jnp mirror of ``pack6_np`` (bit-identical)."""
    c = codes.astype(jnp.uint16)
    assert c.shape[-1] % 4 == 0, c.shape
    c0, c1, c2, c3 = (c[..., i::4] for i in range(4))
    b0 = c0 | (c1 & 0x03) << 6
    b1 = (c1 >> 2) | (c2 & 0x0F) << 4
    b2 = (c2 >> 4) | c3 << 2
    out = jnp.stack([b0, b1, b2], axis=-1)
    return out.reshape(*c.shape[:-1], 3 * c.shape[-1] // 4).astype(jnp.uint8)


def unpack6(packed: jax.Array) -> jax.Array:
    p = packed.astype(jnp.uint16)
    assert p.shape[-1] % 3 == 0, p.shape
    b = p.reshape(*p.shape[:-1], p.shape[-1] // 3, 3)
    b0, b1, b2 = b[..., 0], b[..., 1], b[..., 2]
    c0 = b0 & 0x3F
    c1 = (b0 >> 6) | (b1 & 0x0F) << 2
    c2 = (b1 >> 4) | (b2 & 0x03) << 4
    c3 = b2 >> 2
    out = jnp.stack([c0, c1, c2, c3], axis=-1)
    return out.reshape(*p.shape[:-1], 4 * p.shape[-1] // 3).astype(jnp.uint8)


def pack_codes(codes: jax.Array, width: int) -> jax.Array:
    if width == 8:
        return codes.astype(jnp.uint8)
    return {4: pack4, 6: pack6}[width](codes)


def unpack_codes(packed: jax.Array, width: int) -> jax.Array:
    if width == 8:
        return packed.astype(jnp.uint8)
    return {4: unpack4, 6: unpack6}[width](packed)
