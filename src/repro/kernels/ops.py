"""Public jit'd wrappers for the kernel layer: dispatch + padding + autotune.

``impl`` resolution:
  * 'auto'              -> compiled Pallas on TPU, XLA fallback elsewhere
  * 'pallas'            -> compiled Pallas (TPU)
  * 'pallas_interpret'  -> Pallas interpret mode (CPU correctness runs/tests)
  * 'xla'               -> pure-jnp reference semantics (exact same math)

All entry points accept arbitrary (M, K, N); non-aligned shapes are padded
up to block multiples (zero padding is exact for GEMM and for amax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.formats import decode, e8m0_decode, e8m0_encode, encode, \
    get_mx_format
from ..core.scaling import (BlockScaleConfig, apply_group_scales,
                            compute_block_scales, compute_group_scales,
                            expand_group_scales)
from . import pack as packlib
from . import ref
from .blockscale_gemm import blockscale_gemm_pallas, mx_gemm_pallas
from .exsdotp_gemm import exsdotp_gemm_pallas, default_blocks
from .quant import mx_quant_pallas, quant_blockwise_pallas

__all__ = ["exsdotp_gemm", "blockscale_gemm", "blockscale_blocks",
           "quantize_tensor", "quantize_blockwise", "dequantize_blockwise",
           "mx_quantize", "mx_dequantize", "mx_gemm", "mx_blocks",
           "mx_pack", "mx_unpack", "mx_gemm_packed",
           "resolve_impl"]


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def _pad2(x, bm, bn):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _pad_last2(x, br, bc):
    """Zero-pad the last two dims of ``x[..., R, C]`` to tile multiples
    (per-batch padding: leading dims untouched)."""
    r, c = x.shape[-2], x.shape[-1]
    pr, pc = (-r) % br, (-c) % bc
    if pr or pc:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pr), (0, pc)])
    return x


def exsdotp_gemm(a: jax.Array, b: jax.Array, scale=1.0, *,
                 out_dtype=jnp.float32, impl: str = "auto",
                 blocks=None) -> jax.Array:
    """Expanding GEMM: downcast(scale * A @ B) with fp32 accumulation."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.exsdotp_gemm_ref(a, b, scale, out_dtype=out_dtype)
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = blocks or default_blocks(m, n, k, a.dtype.itemsize)
    a = _pad2(a, bm, bk)
    b = _pad2(b, bk, bn)
    out = exsdotp_gemm_pallas(
        a, b, jnp.asarray(scale, jnp.float32).reshape(1, 1),
        out_dtype=out_dtype, block_m=bm, block_n=bn, block_k=bk,
        interpret=(impl == "pallas_interpret"))
    return out[:m, :n]


def blockscale_blocks(m: int, n: int, k: int,
                      cfg: BlockScaleConfig) -> tuple[int, int, int]:
    """Tile sizes for a block-scaled (M, K) × (K, N) GEMM.

    When a dim is smaller than the configured block, the block shrinks —
    but only down to a *legal* Pallas tile: M is sublane-only (unit 8),
    while N and K land on a lane axis of some operand tile (N for B and
    the output, K for A), where compiled TPU Pallas requires multiples
    of 128.  A narrow-N GEMM (MoE router, small heads) therefore pads N
    up to 128 instead of picking an illegal ``block_n=8``; the padded
    columns are zero, so scales and the GEMM are unaffected.
    """
    bm = min(cfg.block_m, _ceil_mult(m, 8))
    bn = min(cfg.block_n, _ceil_mult(n, 128))
    bk = min(cfg.block_k, _ceil_mult(k, 128))
    return bm, bn, bk


def blockscale_gemm(a: jax.Array, b: jax.Array, *, q_dtype_a, q_dtype_b=None,
                    cfg: BlockScaleConfig = BlockScaleConfig(),
                    out_dtype=jnp.float32, impl: str = "auto") -> jax.Array:
    """Fused block-scaled expanding GEMM (DESIGN.md §3).

    Takes *high-precision* ``a[..., M, K]`` / ``b[K, N]`` (fp32/bf16),
    computes per-(row-tile × K-tile) scales, and quantizes into
    ``q_dtype_a``/``q_dtype_b`` inside the GEMM itself — the quantized
    tensors never round-trip HBM.  fp32 accumulation, one final rounding.

    ``a`` keeps native rank: leading dims are batch, row tiles are
    per-(leading index, row-tile) and never cross a batch/sequence
    boundary, so sharded leading dims survive into the GEMM (no flatten
    before the xla branch; the Pallas branch flattens payload *and*
    scale grid identically, so granularity is the same across impls).
    """
    impl = resolve_impl(impl)
    q_dtype_b = q_dtype_a if q_dtype_b is None else q_dtype_b
    *lead, m, k = a.shape
    _, n = b.shape
    bm, bn, bk = blockscale_blocks(m, n, k, cfg)
    a = _pad_last2(a, bm, bk)
    b = _pad2(b, bk, bn)
    sa = compute_block_scales(a, bm, bk, q_dtype_a,
                              margin=cfg.margin, pow2=cfg.pow2)
    sb = compute_block_scales(b, bk, bn, q_dtype_b,
                              margin=cfg.margin, pow2=cfg.pow2)
    if impl == "xla":
        out = ref.blockscale_gemm_ref(
            a, b, sa, sb, q_dtype_a=q_dtype_a, q_dtype_b=q_dtype_b,
            block_m=bm, block_n=bn, block_k=bk, out_dtype=out_dtype)
    else:
        mp, kp = a.shape[-2], a.shape[-1]
        out = blockscale_gemm_pallas(
            a.reshape(-1, kp), b, sa.reshape(-1, sa.shape[-1]), sb,
            q_dtype_a=q_dtype_a, q_dtype_b=q_dtype_b,
            out_dtype=out_dtype, block_m=bm, block_n=bn, block_k=bk,
            interpret=(impl == "pallas_interpret"))
        out = out.reshape(*lead, mp, out.shape[-1])
    return out[..., :m, :n]


# ------------------------------------------------------------------ MX ----

def mx_blocks(m: int, n: int, k: int, group: int) -> tuple[int, int, int]:
    """Tile sizes for an MX (M, K) × (K, N) GEMM.

    Same legality rules as ``blockscale_blocks`` (lane axes N/K round to
    128, sublane M to 8), plus ``block_k`` must contain whole groups —
    with the standard group of 32 the 128-lane floor already does.
    """
    import math
    bm = min(128, _ceil_mult(m, 8))
    bn = min(128, _ceil_mult(n, 128))
    lk = 128 * group // math.gcd(128, group)   # lcm: lane-legal, whole groups
    bk = min(lk, _ceil_mult(k, lk))
    return bm, bn, bk


def mx_quantize(x: jax.Array, mx, *, impl: str = "auto",
                packed: bool = False):
    """Per-group MX quantization of ``x[..., M, K]`` (DESIGN.md §8).

    Returns ``(q, scales)``: ``q[..., M, K]`` f32 element-format values
    of ``x / s`` and ``scales[..., M, K/group]`` E8M0 pow2 scales, with
    ``x ~= q * s`` broadcast per 1×group strip along K (exact rescale —
    pow2).  Groups never span rows, so leading dims are free batch dims.

    With ``packed=True`` (DESIGN.md §9) the return is the *storage*
    layout instead: ``(payload, scales)`` where ``payload`` is the
    densely packed uint8 bit patterns (FP8: one byte per element, FP6:
    three bytes per four, FP4: one byte per two) and ``scales`` the
    E8M0 uint8 codes — the honest HBM/wire footprint.  The round-trip
    through ``mx_unpack``/``e8m0_decode`` is lossless, so
    ``mx_gemm_packed`` on packed operands is bit-identical to the
    value-space path.
    """
    impl = resolve_impl(impl)
    mx = get_mx_format(mx)
    *lead, m, k = x.shape
    assert k % mx.group == 0, (k, mx.group)
    if impl == "xla":
        q, s = ref.mx_quant_ref(x, mx=mx)
    else:
        bm, _, bk = mx_blocks(m, 1, k, mx.group)
        xp = _pad_last2(x.astype(jnp.float32), bm, bk)
        mp, kp = xp.shape[-2], xp.shape[-1]
        q, s = mx_quant_pallas(xp.reshape(-1, kp), mx=mx, block_m=bm,
                               block_k=bk,
                               interpret=(impl == "pallas_interpret"))
        q = q.reshape(*lead, mp, kp)[..., :m, :k]
        s = s.reshape(*lead, mp, kp // mx.group)[..., :m, :k // mx.group]
    if packed:
        return mx_pack(q, mx), e8m0_encode(s)
    return q, s


def mx_pack(q: jax.Array, mx) -> jax.Array:
    """Pack MX element values ``q[..., K]`` (f32 carrier, already in the
    element format's value set) into dense uint8 storage:
    ``[..., K * width / 8]`` bytes.  K must be a multiple of the group
    (guaranteed by ``mx_quantize``), which covers every pack alignment.
    """
    mx = get_mx_format(mx)
    assert q.shape[-1] % mx.group == 0, (q.shape, mx.group)
    return packlib.pack_codes(encode(q, mx.elem), mx.elem.width)


def mx_unpack(p: jax.Array, mx) -> jax.Array:
    """Unpack dense uint8 storage back to f32 element values
    (``[..., K]`` with ``K = bytes * 8 / width``); exact inverse of
    ``mx_pack`` for every representable value."""
    mx = get_mx_format(mx)
    return decode(packlib.unpack_codes(p, mx.elem.width), mx.elem)


def mx_gemm_packed(ap: jax.Array, sa8: jax.Array, bp: jax.Array,
                   sb8: jax.Array, *, mx_a, mx_b=None,
                   out_dtype=jnp.float32) -> jax.Array:
    """Expanding GEMM straight from packed MX storage (DESIGN.md §9).

    ``(ap, sa8)`` is ``mx_quantize(a[..., M, K], packed=True)``;
    ``(bp, sb8)`` is ``mx_quantize(b.T, packed=True)`` — B's groups run
    along K down each column, so its packed payload is stored
    transposed.  Unpack → exact pow2 dequant (E8M0 codes) → f32
    accumulation → one rounding: bit-identical to
    ``ops.mx_gemm(a, b, impl='xla')`` on the same operands, because the
    pack/unpack round-trip is lossless and the math after it is the
    same.  The payloads never exist at more than ``width/8`` bytes per
    element outside the f32 compute window — this is the memory model
    the wire-byte benchmark measures.
    """
    mx_a = get_mx_format(mx_a)
    mx_b = mx_a if mx_b is None else get_mx_format(mx_b)
    g = mx_a.group
    assert mx_b.group == g, (mx_a.name, mx_b.name)
    af = apply_group_scales(mx_unpack(ap, mx_a), e8m0_decode(sa8), g)
    bf = apply_group_scales(mx_unpack(bp, mx_b), e8m0_decode(sb8), g).T
    acc = jnp.einsum("...mk,kn->...mn", af, bf,
                     preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


def mx_dequantize(q: jax.Array, s: jax.Array, mx) -> jax.Array:
    """``q * s`` per 1×group strip along the last axis (exact for pow2)."""
    mx = get_mx_format(mx)
    return apply_group_scales(q.astype(jnp.float32), s, mx.group)


def mx_gemm(a: jax.Array, b: jax.Array, *, mx_a, mx_b=None,
            out_dtype=jnp.float32, impl: str = "auto") -> jax.Array:
    """Fused MX expanding GEMM (DESIGN.md §8).

    Takes *high-precision* ``a[..., M, K]`` / ``b[K, N]``, computes
    per-(row × group-of-32-along-K) E8M0 scales for ``a`` (per
    (group × column) for ``b``), and quantizes into the MX element
    formats inside the GEMM itself; fp32 accumulation, one final
    rounding.  Leading dims of ``a`` are batch: MX scales are per-row, so
    flattening for the Pallas branch never mixes batches.
    """
    impl = resolve_impl(impl)
    mx_a = get_mx_format(mx_a)
    mx_b = mx_a if mx_b is None else get_mx_format(mx_b)
    g = mx_a.group
    assert mx_b.group == g, (mx_a.name, mx_b.name)
    *lead, m, k = a.shape
    _, n = b.shape
    bm, bn, bk = mx_blocks(m, n, k, g)
    a = _pad_last2(a, bm, bk)
    b = _pad2(b, bk, bn)
    sa = compute_group_scales(a, g, mx_a.elem.max_normal)
    sb = compute_group_scales(b.T, g, mx_b.elem.max_normal).T
    if impl == "xla":
        out = ref.mx_gemm_ref(a, b, sa, sb, mx_a=mx_a, mx_b=mx_b,
                              out_dtype=out_dtype)
    else:
        mp, kp = a.shape[-2], a.shape[-1]
        # scales enter the kernel at element resolution (compact grids
        # would put a 4-lane axis on the scale tiles — compiled-TPU
        # illegal); the expansion is exact, f32, emulation-path only
        sae = expand_group_scales(sa.reshape(-1, sa.shape[-1]), g)
        sbe = expand_group_scales(sb.T, g).T
        out = mx_gemm_pallas(
            a.reshape(-1, kp), b, sae, sbe,
            mx_a=mx_a, mx_b=mx_b, out_dtype=out_dtype,
            block_m=bm, block_n=bn, block_k=bk,
            interpret=(impl == "pallas_interpret"))
        out = out.reshape(*lead, mp, out.shape[-1])
    return out[..., :m, :n]


def _ceil_mult(dim: int, unit: int = 8) -> int:
    """Smallest block size for a dim smaller than the configured block:
    round the dim up to ``unit``.  Sublane axes use the default 8; lane
    axes (the last dim of any operand tile) must pass ``unit=128`` —
    compiled TPU Pallas rejects lane tiles that are not 128-multiples
    (masked on CPU CI because the xla/interpret impls accept them)."""
    return max(unit, dim + (-dim) % unit)


@functools.partial(jax.jit, static_argnames=("q_dtype", "margin"))
def quantize_tensor(x: jax.Array, q_dtype, margin: float = 1.0):
    """Per-tensor scaled quantization (classic FP8 recipe, XLA-fused).

    Returns (q, scale) with x ~= q.astype(f32) * scale.

    A non-finite amax (any ``inf``/``NaN`` element) gets scale 1 so the
    poison propagates through quantize → dequant to the output — an
    ``inf`` scale would silently flush the whole tensor to zero.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    max_normal = jnp.float32(jnp.finfo(q_dtype).max)
    s = jnp.where((amax > 0) & jnp.isfinite(amax),
                  amax / (max_normal * margin), 1.0)
    return (xf / s).astype(q_dtype), s


def quantize_blockwise(x: jax.Array, q_dtype, *, block_m=128, block_n=128,
                       margin: float = 1.0, impl: str = "auto"):
    """Per-block scaled quantization. Returns (q[M,N], scales[gm,gn])."""
    impl = resolve_impl(impl)
    m, n = x.shape
    if impl == "xla":
        x = _pad2(x, block_m, block_n)
        q, s = ref.quant_blockwise_ref(x, q_dtype=q_dtype, block_m=block_m,
                                       block_n=block_n, margin=margin)
        return q[:m, :n], s
    # the kernel pads ragged shapes itself and slices the payload back
    return quant_blockwise_pallas(x, q_dtype=q_dtype, block_m=block_m,
                                  block_n=block_n, margin=margin,
                                  interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def dequantize_blockwise(q: jax.Array, s: jax.Array, *, block_m=128,
                         block_n=128) -> jax.Array:
    m, n = q.shape
    qp = _pad2(q.astype(jnp.float32), block_m, block_n)
    gm, gn = qp.shape[0] // block_m, qp.shape[1] // block_n
    xb = qp.reshape(gm, block_m, gn, block_n) * s[:, None, :, None]
    return xb.reshape(qp.shape)[:m, :n]
