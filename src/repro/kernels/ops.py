"""Public jit'd wrappers for the kernel layer: dispatch + padding + autotune.

``impl`` resolution:
  * 'auto'              -> compiled Pallas on TPU, XLA fallback elsewhere
  * 'pallas'            -> compiled Pallas (TPU)
  * 'pallas_interpret'  -> Pallas interpret mode (CPU correctness runs/tests)
  * 'xla'               -> pure-jnp reference semantics (exact same math)

All entry points accept arbitrary (M, K, N); non-aligned shapes are padded
up to block multiples (zero padding is exact for GEMM and for amax).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

import math

from ..core.formats import decode, e8m0_decode, e8m0_encode, encode, \
    get_mx_format
from ..core.scaling import (BlockScaleConfig, apply_group_scales,
                            compute_block_scales, compute_group_scales,
                            expand_group_scales)
from . import autotune, ref
from .blockscale_gemm import (blockscale_gemm_pallas, mx_gemm_packed_pallas,
                              mx_gemm_pallas)
from .codec import get_codec
from .exsdotp_gemm import exsdotp_gemm_pallas, default_blocks
from .quant import (mx_quant_packed_pallas, mx_quant_pallas,
                    quant_blockwise_pallas)

__all__ = ["exsdotp_gemm", "blockscale_gemm", "blockscale_blocks",
           "quantize_tensor", "quantize_blockwise", "dequantize_blockwise",
           "mx_quantize", "mx_dequantize", "mx_dequantize_packed",
           "mx_gemm", "mx_blocks", "mx_packed_blocks",
           "mx_pack", "mx_unpack", "mx_gemm_packed",
           "mx_quantize_kv", "mx_flash_attention",
           "mx_flash_attention_packed", "attention_blocks",
           "decode_attention", "mx_decode_attention_packed",
           "decode_attention_blocks", "resolve_impl"]


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def _tune_sweep_enabled() -> bool:
    """Whether ``tiles='auto'`` may *measure* on a cache miss.

    Default: sweep only on a real TPU backend — CPU/interp runs (tests,
    CI) answer from the committed cache or fall back to the static
    heuristic, so they stay deterministic and never burn minutes timing
    interpret-mode kernels.  ``REPRO_TUNE_SWEEP=1`` forces sweeping
    anywhere (how ``benchmarks/gemm_sweep.py --tune`` populates the
    committed cache); ``=0`` forbids it even on TPU (DESIGN.md §14).
    """
    env = os.environ.get("REPRO_TUNE_SWEEP")
    if env is not None:
        return env not in ("", "0")
    return jax.default_backend() == "tpu"


def _pad2(x, bm, bn):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _pad_last2(x, br, bc):
    """Zero-pad the last two dims of ``x[..., R, C]`` to tile multiples
    (per-batch padding: leading dims untouched)."""
    r, c = x.shape[-2], x.shape[-1]
    pr, pc = (-r) % br, (-c) % bc
    if pr or pc:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, pr), (0, pc)])
    return x


def exsdotp_gemm(a: jax.Array, b: jax.Array, scale=1.0, *,
                 out_dtype=jnp.float32, impl: str = "auto",
                 blocks=None) -> jax.Array:
    """Expanding GEMM: downcast(scale * A @ B) with fp32 accumulation."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.exsdotp_gemm_ref(a, b, scale, out_dtype=out_dtype)
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = blocks or default_blocks(m, n, k, a.dtype.itemsize)
    a = _pad2(a, bm, bk)
    b = _pad2(b, bk, bn)
    out = exsdotp_gemm_pallas(
        a, b, jnp.asarray(scale, jnp.float32).reshape(1, 1),
        out_dtype=out_dtype, block_m=bm, block_n=bn, block_k=bk,
        interpret=(impl == "pallas_interpret"))
    return out[:m, :n]


def blockscale_blocks(m: int, n: int, k: int,
                      cfg: BlockScaleConfig) -> tuple[int, int, int]:
    """Tile sizes for a block-scaled (M, K) × (K, N) GEMM.

    When a dim is smaller than the configured block, the block shrinks —
    but only down to a *legal* Pallas tile: M is sublane-only (unit 8),
    while N and K land on a lane axis of some operand tile (N for B and
    the output, K for A), where compiled TPU Pallas requires multiples
    of 128.  A narrow-N GEMM (MoE router, small heads) therefore pads N
    up to 128 instead of picking an illegal ``block_n=8``; the padded
    columns are zero, so scales and the GEMM are unaffected.
    """
    bm = min(cfg.block_m, _ceil_mult(m, 8))
    bn = min(cfg.block_n, _ceil_mult(n, 128))
    bk = min(cfg.block_k, _ceil_mult(k, 128))
    return bm, bn, bk


def blockscale_gemm(a: jax.Array, b: jax.Array, *, q_dtype_a, q_dtype_b=None,
                    cfg: BlockScaleConfig = BlockScaleConfig(),
                    out_dtype=jnp.float32, impl: str = "auto",
                    tiles=None) -> jax.Array:
    """Fused block-scaled expanding GEMM (DESIGN.md §3).

    Takes *high-precision* ``a[..., M, K]`` / ``b[K, N]`` (fp32/bf16),
    computes per-(row-tile × K-tile) scales, and quantizes into
    ``q_dtype_a``/``q_dtype_b`` inside the GEMM itself — the quantized
    tensors never round-trip HBM.  fp32 accumulation, one final rounding.

    ``a`` keeps native rank: leading dims are batch, row tiles are
    per-(leading index, row-tile) and never cross a batch/sequence
    boundary, so sharded leading dims survive into the GEMM (no flatten
    before the xla branch; the Pallas branch flattens payload *and*
    scale grid identically, so granularity is the same across impls).

    ``tiles='auto'`` (DESIGN.md §14) looks up tuned *compute* tiles for
    the problem from the autotune cache.  The scale grid stays the
    config's block sizes — candidates only subdivide it (the
    ``scale_block_*`` mechanism), so quantization granularity and the
    results are unchanged; the default (``tiles=None``) is the original
    static heuristic, bit-for-bit.
    """
    impl = resolve_impl(impl)
    q_dtype_b = q_dtype_a if q_dtype_b is None else q_dtype_b
    *lead, m, k = a.shape
    _, n = b.shape
    bm, bn, bk = blockscale_blocks(m, n, k, cfg)
    a = _pad_last2(a, bm, bk)
    b = _pad2(b, bk, bn)
    sa = compute_block_scales(a, bm, bk, q_dtype_a,
                              margin=cfg.margin, pow2=cfg.pow2)
    sb = compute_block_scales(b, bk, bn, q_dtype_b,
                              margin=cfg.margin, pow2=cfg.pow2)
    if impl == "xla":
        out = ref.blockscale_gemm_ref(
            a, b, sa, sb, q_dtype_a=q_dtype_a, q_dtype_b=q_dtype_b,
            block_m=bm, block_n=bn, block_k=bk, out_dtype=out_dtype)
    else:
        mp, kp = a.shape[-2], a.shape[-1]
        cbm, cbn, cbk = bm, bn, bk
        skw = {}
        if tiles == "auto":
            (cbm, cbn, cbk), _ = autotune.blockscale_tiles(
                math.prod(lead) * mp, b.shape[1], kp, (bm, bn, bk),
                q_dtype_a, q_dtype_b, impl=impl,
                sweep=_tune_sweep_enabled())
            skw = dict(scale_block_m=bm, scale_block_n=bn,
                       scale_block_k=bk)
        out = blockscale_gemm_pallas(
            a.reshape(-1, kp), b, sa.reshape(-1, sa.shape[-1]), sb,
            q_dtype_a=q_dtype_a, q_dtype_b=q_dtype_b,
            out_dtype=out_dtype, block_m=cbm, block_n=cbn, block_k=cbk,
            interpret=(impl == "pallas_interpret"), **skw)
        out = out.reshape(*lead, mp, out.shape[-1])
    return out[..., :m, :n]


# ------------------------------------------------------------------ MX ----

def mx_blocks(m: int, n: int, k: int, group: int) -> tuple[int, int, int]:
    """Tile sizes for an MX (M, K) × (K, N) GEMM.

    Same legality rules as ``blockscale_blocks`` (lane axes N/K round to
    128, sublane M to 8), plus ``block_k`` must contain whole groups —
    with the standard group of 32 the 128-lane floor already does.
    """
    bm = min(128, _ceil_mult(m, 8))
    bn = min(128, _ceil_mult(n, 128))
    lk = 128 * group // math.gcd(128, group)   # lcm: lane-legal, whole groups
    bk = min(lk, _ceil_mult(k, lk))
    return bm, bn, bk


def mx_packed_blocks(m: int, n: int, group: int,
                     *codecs) -> tuple[int, int, int]:
    """Tile sizes for the *packed-ref* MX kernels (DESIGN.md §10).

    M/N follow the ``blockscale_blocks`` rules; ``block_k`` must contain
    whole groups AND yield lane-legal packed byte runs for every codec
    involved (``codec.lane_unit``: 128 for FP8, 256 for FP4, 512 for
    FP6 — a 128-multiple of bytes after packing).
    """
    bm = min(128, _ceil_mult(m, 8))
    bn = min(128, _ceil_mult(n, 128))
    bk = group
    for unit in [c.lane_unit for c in codecs] + [128]:
        bk = bk * unit // math.gcd(bk, unit)   # lcm
    return bm, bn, bk


def _pad_group(x: jax.Array, group: int) -> jax.Array:
    """Zero-pad the last axis up to a whole number of groups (the
    ragged-K mask: zeros never raise a group amax, an all-pad group
    gets the neutral scale 1 and a zero payload, and its GEMM
    contribution is exactly 0)."""
    pad = (-x.shape[-1]) % group
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


def mx_quantize(x: jax.Array, mx, *, impl: str = "auto",
                packed: bool = False):
    """Per-group MX quantization of ``x[..., M, K]`` (DESIGN.md §8).

    Returns ``(q, scales)``: ``q[..., M, K]`` f32 element-format values
    of ``x / s`` and ``scales[..., M, ⌈K/group⌉]`` E8M0 pow2 scales,
    with ``x ~= q * s`` broadcast per 1×group strip along K (exact
    rescale — pow2).  Groups never span rows, so leading dims are free
    batch dims.  A ragged K (not a whole number of groups) is
    zero-padded internally: ``q`` is sliced back to ``K`` and the last
    scale covers the partial tail group.

    With ``packed=True`` (DESIGN.md §10) the return is the *storage*
    layout instead: ``(payload, scales)`` where ``payload`` is the
    densely packed uint8 bit patterns (FP8: one byte per element, FP6:
    three bytes per four, FP4: one byte per two) covering
    ``group-padded`` K, and ``scales`` the E8M0 uint8 codes — the
    honest HBM/wire footprint.  On the Pallas impls the kernel *emits*
    the packed payload directly (``mx_quant_packed_pallas``): no byte-
    or f32-wide quantized intermediate exists between the quantize and
    the packed GEMM.  The round-trip through ``mx_unpack``/
    ``e8m0_decode`` is lossless, so ``mx_gemm_packed`` on packed
    operands is bit-identical to the value-space path.
    """
    impl = resolve_impl(impl)
    mx = get_mx_format(mx)
    *lead, m, k = x.shape
    x = _pad_group(x, mx.group)          # ragged K: pad-and-mask
    kg = x.shape[-1]
    if impl == "xla":
        q, s = ref.mx_quant_ref(x, mx=mx)
        if packed:
            return mx_pack(q, mx), e8m0_encode(s)
        return (q[..., :k] if kg != k else q), s
    interp = impl == "pallas_interpret"
    if packed:
        codec = get_codec(mx)
        bm, _, bk = mx_packed_blocks(m, 1, mx.group, codec)
        xp = _pad_last2(x.astype(jnp.float32), bm, bk)
        mp, kp = xp.shape[-2], xp.shape[-1]
        p, s8 = mx_quant_packed_pallas(xp.reshape(-1, kp), mx=mx,
                                       block_m=bm, block_k=bk,
                                       interpret=interp)
        p = p.reshape(*lead, mp, codec.packed_cols(kp))[
            ..., :m, :codec.packed_cols(kg)]
        s8 = s8.reshape(*lead, mp, kp // mx.group)[..., :m, :kg // mx.group]
        return p, s8
    bm, _, bk = mx_blocks(m, 1, kg, mx.group)
    xp = _pad_last2(x.astype(jnp.float32), bm, bk)
    mp, kp = xp.shape[-2], xp.shape[-1]
    q, s = mx_quant_pallas(xp.reshape(-1, kp), mx=mx, block_m=bm,
                           block_k=bk, interpret=interp)
    q = q.reshape(*lead, mp, kp)[..., :m, :k]
    s = s.reshape(*lead, mp, kp // mx.group)[..., :m, :kg // mx.group]
    return q, s


def mx_pack(q: jax.Array, mx) -> jax.Array:
    """Pack MX element values ``q[..., K]`` (f32 carrier, already in the
    element format's value set) into dense uint8 storage:
    ``[..., ⌈K/align⌉ * width / 8]`` bytes via the payload codec.  A
    ragged K is zero-padded to the pack alignment (zero codes decode to
    +0.0 — ``mx_unpack(..., k=K)`` slices the tail back off)."""
    mx = get_mx_format(mx)
    codec = get_codec(mx)
    pad = (-q.shape[-1]) % codec.pack_align
    if pad:
        q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    return codec.encode_lanes(q)


def mx_unpack(p: jax.Array, mx, *, k=None) -> jax.Array:
    """Unpack dense uint8 storage back to f32 element values
    (``[..., K]`` with ``K = bytes * 8 / width``, sliced to ``k`` when
    given — the ragged-shape inverse); exact inverse of ``mx_pack`` for
    every representable value."""
    vals = get_codec(get_mx_format(mx)).decode_lanes(p)
    return vals[..., :k] if k is not None else vals


def mx_gemm_packed(ap: jax.Array, sa8: jax.Array, bp: jax.Array,
                   sb8: jax.Array, *, mx_a, mx_b=None,
                   out_dtype=jnp.float32, impl: str = "auto",
                   tiles=None) -> jax.Array:
    """Expanding GEMM straight from packed MX storage (DESIGN.md §10).

    ``(ap, sa8)`` is ``mx_quantize(a[..., M, K], packed=True)``;
    ``(bp, sb8)`` is ``mx_quantize(b.T, packed=True)`` — B's groups run
    along K down each column, so its packed payload is stored
    transposed.  Unpack → exact pow2 dequant (E8M0 codes) → f32
    accumulation → one rounding: bit-identical to
    ``ops.mx_gemm(a, b, impl='xla')`` on the same operands, because the
    pack/unpack round-trip is lossless and the math after it is the
    same.  On the Pallas impls the packed refs enter the kernel as-is:
    VMEM holds ``width/8`` bytes per element and the unpack/decode
    happens in-register per K-tile (``mx_gemm_packed_pallas``) — the
    payloads never exist byte-wide outside the registers.  This is the
    memory model the wire-byte benchmark measures.  K may be
    group-padded relative to the logical shapes (``mx_quantize`` pads
    ragged K): padded groups contribute exactly zero.

    ``tiles='auto'`` (DESIGN.md §14) replaces the static
    ``mx_packed_blocks`` heuristic with tuned (block_m, block_n,
    block_k) tiles *and* the tuned K-loop streaming schedule
    (grid-pipelined vs double-buffered manual DMA) from the autotune
    cache.  MX group scales are a property of the layout (groups of 32
    along K), not of the tile grid, so any tuned choice is bit-exact vs
    the default on exact-arithmetic operands.
    """
    impl = resolve_impl(impl)
    mx_a = get_mx_format(mx_a)
    mx_b = mx_a if mx_b is None else get_mx_format(mx_b)
    g = mx_a.group
    assert mx_b.group == g, (mx_a.name, mx_b.name)
    if impl == "xla":
        af = apply_group_scales(mx_unpack(ap, mx_a), e8m0_decode(sa8), g)
        bf = apply_group_scales(mx_unpack(bp, mx_b), e8m0_decode(sb8), g).T
        acc = jnp.einsum("...mk,kn->...mn", af, bf,
                         preferred_element_type=jnp.float32)
        return acc.astype(out_dtype)
    ca, cb = get_codec(mx_a), get_codec(mx_b)
    *lead, m, _ = ap.shape
    n = bp.shape[0]
    k = sa8.shape[-1] * g
    assert ap.shape[-1] == ca.packed_cols(k), (ap.shape, k)
    assert bp.shape == (n, cb.packed_cols(k)), (bp.shape, (n, k))
    assert sb8.shape == (n, k // g), (sb8.shape, (n, k // g))
    bm, bn, bk = mx_packed_blocks(m, n, g, ca, cb)
    db = False
    if tiles == "auto":
        (bm, bn, bk), db, _ = autotune.gemm_packed_tiles(
            math.prod(lead) * m, n, k, mx_a, mx_b, impl=impl,
            sweep=_tune_sweep_enabled())
    # scale codes enter the kernel at element resolution (compact grids
    # would be lane-illegal on compiled TPU — the §8 rule, now one u8
    # per element instead of the value-path's f32)
    sae8 = jnp.repeat(sa8.reshape(-1, k // g), g, axis=-1)
    sbe8 = jnp.repeat(sb8, g, axis=-1)
    # pad rows to tile multiples and K to whole packed lane tiles; zero
    # payload bytes decode to +0.0 and zero scale codes to 2^-127, so
    # padded contributions are exactly 0
    ap2 = _pad2(ap.reshape(-1, ap.shape[-1]), bm, ca.packed_cols(bk))
    sae8 = _pad2(sae8, bm, bk)
    bp2 = _pad2(bp, bn, cb.packed_cols(bk))
    sbe8 = _pad2(sbe8, bn, bk)
    out = mx_gemm_packed_pallas(
        ap2, bp2, sae8, sbe8, mx_a=mx_a, mx_b=mx_b, out_dtype=out_dtype,
        block_m=bm, block_n=bn, block_k=bk, double_buffer=db,
        interpret=(impl == "pallas_interpret"))
    return out[:ap.reshape(-1, ap.shape[-1]).shape[0], :n].reshape(
        *lead, m, n)


# --------------------------------------------------- MX attention ----

def attention_blocks(s: int, t: int) -> "tuple[int, int] | None":
    """(block_q, block_k) for a flash-attention sweep over S × T, or
    None when no legal tiling exists.

    Picks the largest power-of-two tile ≤ 128 that divides each length
    (floor 8 — the sublane unit; the kernels assert exact divisibility
    rather than padding, because attention masks are positional and a
    padded length would need an extra in-kernel mask).
    """
    def pick(n):
        for b in (128, 64, 32, 16, 8):
            if n % b == 0:
                return b
        return None

    bq, bk = pick(s), pick(t)
    return (bq, bk) if bq and bk else None


def mx_quantize_kv(kv: jax.Array, mx, *, impl: str = "auto"):
    """Attention-shaped packed MX quantize: ``kv[..., T, hd]`` with
    E8M0 group scales over the *head* dimension (DESIGN.md §11).

    Thin shape-checked wrapper over ``mx_quantize(packed=True)`` — hd
    must be a whole number of groups (no ragged tail: the head axis is
    the q·kᵀ contraction, and a padded head dim would change
    ``scale = hd**-0.5``).  Returns ``(payload [..., T, hd·w/8] u8,
    scales [..., T, hd/group] u8)``.
    """
    mx = get_mx_format(mx)
    hd = kv.shape[-1]
    assert hd % mx.group == 0, (hd, mx.group)
    return mx_quantize(kv, mx, impl=impl, packed=True)


def mx_flash_attention_packed(q: jax.Array, kp: jax.Array, ks8: jax.Array,
                              vp: jax.Array, vs8: jax.Array, *, mx_k,
                              mx_v=None, causal: bool = True,
                              block_q=None, block_k=None,
                              impl: str = "auto",
                              tiles=None) -> jax.Array:
    """Flash attention straight from packed MX KV storage (DESIGN.md
    §11) — the attention analogue of ``mx_gemm_packed``.

    ``q [BH, S, hd]`` carrier precision; ``(kp, ks8)`` / ``(vp, vs8)``
    from ``mx_quantize_kv``.  On the Pallas impls the packed refs enter
    the kernel as-is and decode in-register per KV tile
    (``mx_flash_attention_pallas``); the xla branch dequantizes (exact
    — pow2 scales) and runs the straight-softmax reference — identical
    math up to f32 summation order and the online-softmax rescale,
    which exact-arithmetic operands make bitwise equal.

    ``tiles='auto'`` (DESIGN.md §14) replaces the static
    ``attention_blocks`` tile pick with the tuned (block_q, block_k)
    from the autotune cache — candidates divide S/T exactly, so the
    sweep visits the same (query, KV) pairs in the same online-softmax
    order per q row; explicit ``block_q``/``block_k`` still win.
    """
    from .flash_attention import mx_flash_attention_pallas
    impl = resolve_impl(impl)
    mx_k = get_mx_format(mx_k)
    mx_v = mx_k if mx_v is None else get_mx_format(mx_v)
    hd = q.shape[-1]
    if impl == "xla":
        kf = mx_dequantize_packed(kp, ks8, mx_k, k=hd).astype(jnp.float32)
        vf = mx_dequantize_packed(vp, vs8, mx_v, k=hd).astype(jnp.float32)
        return ref.flash_attention_ref(q, kf, vf, causal=causal)
    if tiles == "auto":
        (bq, bk), _ = autotune.attention_tiles(
            "mx_flash", q.shape[0], q.shape[1], kp.shape[1], hd,
            fmt_k=mx_k, fmt_v=mx_v, causal=causal, impl=impl,
            sweep=_tune_sweep_enabled())
    else:
        blocks = attention_blocks(q.shape[1], kp.shape[1])
        assert blocks is not None, (q.shape, kp.shape)
        bq, bk = blocks
    return mx_flash_attention_pallas(
        q, kp, ks8, vp, vs8, mx_k=mx_k, mx_v=mx_v, causal=causal,
        block_q=block_q or bq, block_k=block_k or bk,
        interpret=(impl == "pallas_interpret"))


def decode_attention_blocks(s: int, t: int) -> tuple[int, int]:
    """(block_q, block_k) for a decode sweep over S query rows × T cache
    slots.  Unlike ``attention_blocks`` this never fails: decode S is
    often 1 (or a prompt length with no structure), so the q tile falls
    through the pow2 ladder down to 1 and the KV tile down to 8.  Tiles
    below the sublane/lane units are interpret/CPU-only — the same
    legality convention as the §11 kernels; real-TPU serving picks
    aligned page sizes.
    """
    def pick(n, floor):
        for b in (128, 64, 32, 16, 8, 4, 2, 1):
            if b >= floor and n % b == 0:
                return b
        return 1

    return pick(s, 1), pick(t, 8)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lens: jax.Array, *, block_q=None, block_k=None,
                     impl: str = "auto", tiles=None) -> jax.Array:
    """Serving attention over a carrier-precision cache (DESIGN.md §12).

    ``q [BH, S, hd]`` rows at absolute slots ``lens + i`` against cache
    ``k/v [BH, T, hd]``; slots beyond the live prefix ``lens + S`` are
    structurally excluded (garbage pages).  Pallas impls run the
    base-offset online-softmax sweep with the page-skip; the xla branch
    is ``ref.decode_attention_ref`` — identical math.  ``tiles='auto'``
    swaps the static ``decode_attention_blocks`` pick for the tuned
    (block_q, block_k) from the autotune cache (DESIGN.md §14).
    """
    from .decode_attention import decode_attention_pallas
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.decode_attention_ref(q, k, v, lens)
    if tiles == "auto":
        (bq, bk), _ = autotune.attention_tiles(
            "decode", q.shape[0], q.shape[1], k.shape[1], q.shape[-1],
            impl=impl, sweep=_tune_sweep_enabled())
    else:
        bq, bk = decode_attention_blocks(q.shape[1], k.shape[1])
    return decode_attention_pallas(
        q, k, v, lens, block_q=block_q or bq, block_k=block_k or bk,
        interpret=(impl == "pallas_interpret"))


def mx_decode_attention_packed(q: jax.Array, kp: jax.Array, ks8: jax.Array,
                               vp: jax.Array, vs8: jax.Array,
                               lens: jax.Array, *, mx_k, mx_v=None,
                               block_q=None, block_k=None,
                               impl: str = "auto", tiles=None) -> jax.Array:
    """Serving attention straight from the packed paged KV cache
    (DESIGN.md §12) — the decode analogue of
    ``mx_flash_attention_packed``.

    ``(kp, ks8)`` / ``(vp, vs8)`` are gathered page slots in
    ``mx_quantize_kv`` layout; ``lens [BH]`` the live lengths.  On the
    Pallas impls the packed slots decode in-register per KV tile
    (``mx_decode_attention_pallas``); the xla branch dequantizes (exact
    — pow2 scales) and runs the masked reference.  Garbage slots beyond
    ``lens + S`` are excluded structurally on every impl, so stale
    NaN-scale poison in freed pages never reaches live rows.
    ``tiles='auto'`` swaps the static ``decode_attention_blocks`` pick
    for the tuned (block_q, block_k) from the autotune cache
    (DESIGN.md §14); explicit ``block_q``/``block_k`` still win.
    """
    from .decode_attention import mx_decode_attention_pallas
    impl = resolve_impl(impl)
    mx_k = get_mx_format(mx_k)
    mx_v = mx_k if mx_v is None else get_mx_format(mx_v)
    hd = q.shape[-1]
    if impl == "xla":
        kf = mx_dequantize_packed(kp, ks8, mx_k, k=hd)
        vf = mx_dequantize_packed(vp, vs8, mx_v, k=hd)
        return ref.decode_attention_ref(q, kf, vf, lens)
    if tiles == "auto":
        (bq, bk), _ = autotune.attention_tiles(
            "mx_decode", q.shape[0], q.shape[1], kp.shape[1], hd,
            fmt_k=mx_k, fmt_v=mx_v, impl=impl,
            sweep=_tune_sweep_enabled())
    else:
        bq, bk = decode_attention_blocks(q.shape[1], kp.shape[1])
    return mx_decode_attention_pallas(
        q, kp, ks8, vp, vs8, lens, mx_k=mx_k, mx_v=mx_v,
        block_q=block_q or bq, block_k=block_k or bk,
        interpret=(impl == "pallas_interpret"))


def mx_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, mx_k,
                       mx_v=None, causal: bool = True, block_q=None,
                       block_k=None, impl: str = "auto",
                       tiles=None) -> jax.Array:
    """Quantized-KV flash attention from high-precision operands:
    ``mx_quantize_kv`` both KV tensors (groups of 32 along hd, E8M0
    scales, packed payloads), then ``mx_flash_attention_packed``.
    q and the online-softmax state stay wide — only the streamed KV
    operands narrow (the forward-path regime of Noune et al.
    2206.02915).  ``tiles='auto'`` passes through to the packed sweep.
    """
    mx_k = get_mx_format(mx_k)
    mx_v = mx_k if mx_v is None else get_mx_format(mx_v)
    kp, ks8 = mx_quantize_kv(k, mx_k, impl=impl)
    vp, vs8 = mx_quantize_kv(v, mx_v, impl=impl)
    return mx_flash_attention_packed(
        q, kp, ks8, vp, vs8, mx_k=mx_k, mx_v=mx_v, causal=causal,
        block_q=block_q, block_k=block_k, impl=impl, tiles=tiles)


def mx_dequantize(q: jax.Array, s: jax.Array, mx) -> jax.Array:
    """``q * s`` per 1×group strip along the last axis (exact for pow2)."""
    mx = get_mx_format(mx)
    return apply_group_scales(q.astype(jnp.float32), s, mx.group)


def mx_dequantize_packed(p: jax.Array, s8: jax.Array, mx, *,
                         k=None) -> jax.Array:
    """Packed payload + E8M0 codes → f32 values: unpack, decode the
    byte grid (exact — pow2; 0xFF → NaN) and rescale per group, slicing
    a group-padded K back to ``k`` when given.  The storage-layer
    inverse of ``mx_quantize(packed=True)``."""
    mx = get_mx_format(mx)
    x = apply_group_scales(mx_unpack(p, mx), e8m0_decode(s8), mx.group)
    return x[..., :k] if k is not None else x


def mx_gemm(a: jax.Array, b: jax.Array, *, mx_a, mx_b=None,
            out_dtype=jnp.float32, impl: str = "auto") -> jax.Array:
    """Fused MX expanding GEMM (DESIGN.md §8).

    Takes *high-precision* ``a[..., M, K]`` / ``b[K, N]``, computes
    per-(row × group-of-32-along-K) E8M0 scales for ``a`` (per
    (group × column) for ``b``), and quantizes into the MX element
    formats inside the GEMM itself; fp32 accumulation, one final
    rounding.  Leading dims of ``a`` are batch: MX scales are per-row, so
    flattening for the Pallas branch never mixes batches.
    """
    impl = resolve_impl(impl)
    mx_a = get_mx_format(mx_a)
    mx_b = mx_a if mx_b is None else get_mx_format(mx_b)
    g = mx_a.group
    assert mx_b.group == g, (mx_a.name, mx_b.name)
    *lead, m, k = a.shape
    _, n = b.shape
    bm, bn, bk = mx_blocks(m, n, k, g)
    a = _pad_last2(a, bm, bk)
    b = _pad2(b, bk, bn)
    sa = compute_group_scales(a, g, mx_a.elem.max_normal)
    sb = compute_group_scales(b.T, g, mx_b.elem.max_normal).T
    if impl == "xla":
        out = ref.mx_gemm_ref(a, b, sa, sb, mx_a=mx_a, mx_b=mx_b,
                              out_dtype=out_dtype)
    else:
        mp, kp = a.shape[-2], a.shape[-1]
        # scales enter the kernel at element resolution (compact grids
        # would put a 4-lane axis on the scale tiles — compiled-TPU
        # illegal); the expansion is exact, f32, emulation-path only
        sae = expand_group_scales(sa.reshape(-1, sa.shape[-1]), g)
        sbe = expand_group_scales(sb.T, g).T
        out = mx_gemm_pallas(
            a.reshape(-1, kp), b, sae, sbe,
            mx_a=mx_a, mx_b=mx_b, out_dtype=out_dtype,
            block_m=bm, block_n=bn, block_k=bk,
            interpret=(impl == "pallas_interpret"))
        out = out.reshape(*lead, mp, out.shape[-1])
    return out[..., :m, :n]


def _ceil_mult(dim: int, unit: int = 8) -> int:
    """Smallest block size for a dim smaller than the configured block:
    round the dim up to ``unit``.  Sublane axes use the default 8; lane
    axes (the last dim of any operand tile) must pass ``unit=128`` —
    compiled TPU Pallas rejects lane tiles that are not 128-multiples
    (masked on CPU CI because the xla/interpret impls accept them)."""
    return max(unit, dim + (-dim) % unit)


@functools.partial(jax.jit, static_argnames=("q_dtype", "margin"))
def quantize_tensor(x: jax.Array, q_dtype, margin: float = 1.0):
    """Per-tensor scaled quantization (classic FP8 recipe, XLA-fused).

    Returns (q, scale) with x ~= q.astype(f32) * scale.

    A non-finite amax (any ``inf``/``NaN`` element) gets scale 1 so the
    poison propagates through quantize → dequant to the output — an
    ``inf`` scale would silently flush the whole tensor to zero.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    max_normal = jnp.float32(jnp.finfo(q_dtype).max)
    s = jnp.where((amax > 0) & jnp.isfinite(amax),
                  amax / (max_normal * margin), 1.0)
    return (xf / s).astype(q_dtype), s


def quantize_blockwise(x: jax.Array, q_dtype, *, block_m=128, block_n=128,
                       margin: float = 1.0, impl: str = "auto"):
    """Per-block scaled quantization. Returns (q[M,N], scales[gm,gn])."""
    impl = resolve_impl(impl)
    m, n = x.shape
    if impl == "xla":
        x = _pad2(x, block_m, block_n)
        q, s = ref.quant_blockwise_ref(x, q_dtype=q_dtype, block_m=block_m,
                                       block_n=block_n, margin=margin)
        return q[:m, :n], s
    # the kernel pads ragged shapes itself and slices the payload back
    return quant_blockwise_pallas(x, q_dtype=q_dtype, block_m=block_m,
                                  block_n=block_n, margin=margin,
                                  interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def dequantize_blockwise(q: jax.Array, s: jax.Array, *, block_m=128,
                         block_n=128) -> jax.Array:
    m, n = q.shape
    qp = _pad2(q.astype(jnp.float32), block_m, block_n)
    gm, gn = qp.shape[0] // block_m, qp.shape[1] // block_n
    xb = qp.reshape(gm, block_m, gn, block_n) * s[:, None, :, None]
    return xb.reshape(qp.shape)[:m, :n]
