"""Public jit'd wrappers for the kernel layer: dispatch + padding + autotune.

``impl`` resolution:
  * 'auto'              -> compiled Pallas on TPU, XLA fallback elsewhere
  * 'pallas'            -> compiled Pallas (TPU)
  * 'pallas_interpret'  -> Pallas interpret mode (CPU correctness runs/tests)
  * 'xla'               -> pure-jnp reference semantics (exact same math)

All entry points accept arbitrary (M, K, N); non-aligned shapes are padded
up to block multiples (zero padding is exact for GEMM and for amax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .exsdotp_gemm import exsdotp_gemm_pallas, default_blocks
from .quant import quant_blockwise_pallas

__all__ = ["exsdotp_gemm", "quantize_tensor", "quantize_blockwise",
           "dequantize_blockwise", "resolve_impl"]


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def _pad2(x, bm, bn):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def exsdotp_gemm(a: jax.Array, b: jax.Array, scale=1.0, *,
                 out_dtype=jnp.float32, impl: str = "auto",
                 blocks=None) -> jax.Array:
    """Expanding GEMM: downcast(scale * A @ B) with fp32 accumulation."""
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.exsdotp_gemm_ref(a, b, scale, out_dtype=out_dtype)
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = blocks or default_blocks(m, n, k, a.dtype.itemsize)
    a = _pad2(a, bm, bk)
    b = _pad2(b, bk, bn)
    out = exsdotp_gemm_pallas(
        a, b, jnp.asarray(scale, jnp.float32).reshape(1, 1),
        out_dtype=out_dtype, block_m=bm, block_n=bn, block_k=bk,
        interpret=(impl == "pallas_interpret"))
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("q_dtype", "margin"))
def quantize_tensor(x: jax.Array, q_dtype, margin: float = 1.0):
    """Per-tensor scaled quantization (classic FP8 recipe, XLA-fused).

    Returns (q, scale) with x ~= q.astype(f32) * scale.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    max_normal = jnp.float32(jnp.finfo(q_dtype).max)
    s = jnp.where(amax > 0, amax / (max_normal * margin), 1.0)
    return (xf / s).astype(q_dtype), s


def quantize_blockwise(x: jax.Array, q_dtype, *, block_m=128, block_n=128,
                       margin: float = 1.0, impl: str = "auto"):
    """Per-block scaled quantization. Returns (q[M,N], scales[gm,gn])."""
    impl = resolve_impl(impl)
    m, n = x.shape
    if impl == "xla":
        x = _pad2(x, block_m, block_n)
        q, s = ref.quant_blockwise_ref(x, q_dtype=q_dtype, block_m=block_m,
                                       block_n=block_n, margin=margin)
        return q[:m, :n], s
    x = _pad2(x, block_m, block_n)
    q, s = quant_blockwise_pallas(x, q_dtype=q_dtype, block_m=block_m,
                                  block_n=block_n, margin=margin,
                                  interpret=(impl == "pallas_interpret"))
    return q[:m, :n], s


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def dequantize_blockwise(q: jax.Array, s: jax.Array, *, block_m=128,
                         block_n=128) -> jax.Array:
    m, n = q.shape
    qp = _pad2(q.astype(jnp.float32), block_m, block_n)
    gm, gn = qp.shape[0] // block_m, qp.shape[1] // block_n
    xb = qp.reshape(gm, block_m, gn, block_n) * s[:, None, :, None]
    return xb.reshape(qp.shape)[:m, :n]
