"""Fused block-scaled ExSdotp GEMM — Pallas TPU kernel (DESIGN.md §3).

The per-tensor pipeline costs an extra HBM round-trip: quantize writes
``q`` (and re-reads ``x``), then the GEMM reads ``q`` again.  Here the
cast happens *inside* the GEMM kernel: high-precision (fp32/bf16) tiles
stream HBM→VMEM once, are divided by their per-block scale and cast to
the minifloat format in VMEM, multiplied on the MXU, and the partial
product is rescaled by ``sa * sb`` into the fp32 accumulator.  The
quantized tensor never exists in HBM.

Scales are precomputed per (row-tile × K-tile) by
``core.scaling.compute_block_scales`` — a tiny reduce, grid-mapped into
SMEM so each (i, j, k) step reads exactly the two scalars it needs.
Because the rescale is applied at *accumulator granularity* (once per
K-tile partial product, inside the fp32 accumulator), the ExSdotp
structure of eq. 1 is preserved per block: multiply narrow, accumulate
wide across the whole K loop, round once on the final write.

With pow2 scales (the default) the divide and the rescale are exact, so
the only rounding anywhere is (a) the mantissa cast into the minifloat
format and (b) the single final downcast — the same two roundings the
paper's hardware performs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["blockscale_gemm_pallas"]


def _kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref,
            *, q_dtype_a, q_dtype_b):
    """One (i, j, k) grid step of the fused quantize+GEMM.

    acc += dequant(cast(A_ik / sa), cast(B_kj / sb)) with the per-block
    rescale ``sa * sb`` folded into the accumulator update; single
    rounding into ``o_ref.dtype`` on the last K step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sa = sa_ref[0, 0]
    sb = sb_ref[0, 0]
    # quantize in VMEM: one scale per (block_m, block_k) / (block_k,
    # block_n) tile — the CAST unit fused into the GEMM's stream
    aq = (a_ref[...].astype(jnp.float32) / sa).astype(q_dtype_a)
    bq = (b_ref[...].astype(jnp.float32) / sb).astype(q_dtype_b)
    # expanding multiply + per-block dequant at accumulator granularity
    acc_ref[...] += jnp.dot(
        aq.astype(jnp.float32), bq.astype(jnp.float32),
        preferred_element_type=jnp.float32) * (sa * sb)

    @pl.when(k == pl.num_programs(2) - 1)
    def _write():
        # the single rounding of the whole per-output-tile ExSdotp chain
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("q_dtype_a", "q_dtype_b", "out_dtype",
                     "block_m", "block_n", "block_k", "interpret"))
def blockscale_gemm_pallas(a: jax.Array, b: jax.Array,
                           sa: jax.Array, sb: jax.Array, *,
                           q_dtype_a, q_dtype_b, out_dtype=jnp.float32,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """C = downcast(sum_k (A_ik/sa→q)·(B_kj/sb→q) · sa·sb), fp32 accum.

    ``a[M, K]``/``b[K, N]`` are high-precision (fp32/bf16) operands;
    ``sa[M/bm, K/bk]``/``sb[K/bk, N/bn]`` are per-block dequant scales
    (f32, from ``core.scaling.compute_block_scales``).  Shapes must be
    multiples of the block sizes (``ops.py`` pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    assert sa.shape == (m // block_m, k // block_k), sa.shape
    assert sb.shape == (k // block_k, n // block_n), sb.shape
    grid = (m // block_m, n // block_n, k // block_k)
    kern = functools.partial(_kernel, q_dtype_a=jnp.dtype(q_dtype_a),
                             q_dtype_b=jnp.dtype(q_dtype_b))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, sa.astype(jnp.float32), sb.astype(jnp.float32))
