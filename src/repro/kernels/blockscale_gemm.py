"""Fused block-scaled ExSdotp GEMM — Pallas TPU kernel (DESIGN.md §3).

The per-tensor pipeline costs an extra HBM round-trip: quantize writes
``q`` (and re-reads ``x``), then the GEMM reads ``q`` again.  Here the
cast happens *inside* the GEMM kernel: high-precision (fp32/bf16) tiles
stream HBM→VMEM once, are divided by their per-block scale and cast to
the minifloat format in VMEM, multiplied on the MXU, and the partial
product is rescaled by ``sa * sb`` into the fp32 accumulator.  The
quantized tensor never exists in HBM.

Scales are precomputed per (row-tile × K-tile) by
``core.scaling.compute_block_scales`` — a tiny reduce, grid-mapped into
SMEM so each (i, j, k) step reads exactly the two scalars it needs.
Because the rescale is applied at *accumulator granularity* (once per
K-tile partial product, inside the fp32 accumulator), the ExSdotp
structure of eq. 1 is preserved per block: multiply narrow, accumulate
wide across the whole K loop, round once on the final write.

With pow2 scales (the default) the divide and the rescale are exact, so
the only rounding anywhere is (a) the mantissa cast into the minifloat
format and (b) the single final downcast — the same two roundings the
paper's hardware performs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.formats import _quantize_f32, e8m0_decode, get_mx_format
from ._compat import CompilerParams
from .codec import get_codec

__all__ = ["blockscale_gemm_pallas", "mx_gemm_pallas",
           "mx_gemm_packed_pallas"]


def _kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref,
            *, q_dtype_a, q_dtype_b):
    """One (i, j, k) grid step of the fused quantize+GEMM.

    acc += dequant(cast(A_ik / sa), cast(B_kj / sb)) with the per-block
    rescale ``sa * sb`` folded into the accumulator update; single
    rounding into ``o_ref.dtype`` on the last K step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sa = sa_ref[0, 0]
    sb = sb_ref[0, 0]
    # quantize in VMEM: one scale per (block_m, block_k) / (block_k,
    # block_n) tile — the CAST unit fused into the GEMM's stream
    aq = (a_ref[...].astype(jnp.float32) / sa).astype(q_dtype_a)
    bq = (b_ref[...].astype(jnp.float32) / sb).astype(q_dtype_b)
    # expanding multiply + per-block dequant at accumulator granularity
    acc_ref[...] += jnp.dot(
        aq.astype(jnp.float32), bq.astype(jnp.float32),
        preferred_element_type=jnp.float32) * (sa * sb)

    @pl.when(k == pl.num_programs(2) - 1)
    def _write():
        # the single rounding of the whole per-output-tile ExSdotp chain
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("q_dtype_a", "q_dtype_b", "out_dtype",
                     "block_m", "block_n", "block_k", "scale_block_m",
                     "scale_block_n", "scale_block_k", "interpret"))
def blockscale_gemm_pallas(a: jax.Array, b: jax.Array,
                           sa: jax.Array, sb: jax.Array, *,
                           q_dtype_a, q_dtype_b, out_dtype=jnp.float32,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 128,
                           scale_block_m=None, scale_block_n=None,
                           scale_block_k=None,
                           interpret: bool = False) -> jax.Array:
    """C = downcast(sum_k (A_ik/sa→q)·(B_kj/sb→q) · sa·sb), fp32 accum.

    ``a[M, K]``/``b[K, N]`` are high-precision (fp32/bf16) operands;
    ``sa[M/sm, K/sk]``/``sb[K/sk, N/sn]`` are per-block dequant scales
    (f32, from ``core.scaling.compute_block_scales``).

    Tile-legality contract (DESIGN.md §3/§14): shapes must be multiples
    of the compute tiles (``ops.py`` pads); ``block_m`` is a sublane
    8-multiple while ``block_n``/``block_k`` land on lane axes and must
    be 128-multiples on compiled TPU (interp/CPU CI masks violations —
    the ``ops.blockscale_blocks`` convention).  The *scale* blocks
    ``scale_block_*`` (default: the compute tiles — the original
    kernel) may be coarser than the compute tiles as long as each
    compute tile sits inside exactly one scale block (``sm % bm == 0``
    etc., so every (i, kk) step still reads one scalar per operand from
    SMEM): that is how the §14 autotuner sweeps compute tiles without
    touching the scale-granularity numerics contract.
    """
    sm = block_m if scale_block_m is None else scale_block_m
    sn = block_n if scale_block_n is None else scale_block_n
    sk = block_k if scale_block_k is None else scale_block_k
    assert sm % block_m == 0 and sn % block_n == 0 and sk % block_k == 0, (
        (sm, sn, sk), (block_m, block_n, block_k))
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    assert m % sm == 0 and n % sn == 0 and k % sk == 0, ((m, n, k),
                                                        (sm, sn, sk))
    assert sa.shape == (m // sm, k // sk), (sa.shape, (m // sm, k // sk))
    assert sb.shape == (k // sk, n // sn), (sb.shape, (k // sk, n // sn))
    grid = (m // block_m, n // block_n, k // block_k)
    kern = functools.partial(_kernel, q_dtype_a=jnp.dtype(q_dtype_a),
                             q_dtype_b=jnp.dtype(q_dtype_b))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1),
                         lambda i, j, kk: (i * block_m // sm,
                                           kk * block_k // sk),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1),
                         lambda i, j, kk: (kk * block_k // sk,
                                           j * block_n // sn),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, sa.astype(jnp.float32), sb.astype(jnp.float32))


# ----------------------------------------------------------------- MX ------
# Same fused structure at MX granularity (DESIGN.md §8).  Scales enter
# at *element resolution* (sae[M, K], sbe[K, N] — each group's scale
# pre-broadcast over its 32 elements): compact (M, K/32) grids would put
# a 4-lane axis on the scale tiles, which compiled TPU Pallas rejects
# (lane dims must be 128-multiples — the blockscale_blocks rule; masked
# on CPU CI).  The f32 expansion costs emulation-path bandwidth only; a
# production kernel would carry packed E8M0 bytes.  Because E8M0 scales
# are powers of two, multiplying the *elements* by their group scale
# before the MXU dot is bit-identical to rescaling each group's partial
# product after it: per-group dequant at accumulator granularity with no
# per-group inner loop.

def _mx_kernel(a_ref, b_ref, sae_ref, sbe_ref, o_ref, acc_ref,
               *, fmt_a, fmt_b):
    """One (i, j, k) grid step of the fused MX quantize+GEMM.

    acc += dequant(cast(A/sa), cast(B/sb)) with each element carrying its
    own group's exact pow2 rescale into the f32 accumulator; a NaN (E8M0
    0xFF) group scale poisons exactly that group's contributions.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sae = sae_ref[...]
    sbe = sbe_ref[...]
    # quantize in VMEM: value-space element cast (bit-identical to the
    # native cast where one exists; FP6/FP4 have none)
    aq = _quantize_f32(a_ref[...].astype(jnp.float32) / sae, fmt_a)
    bq = _quantize_f32(b_ref[...].astype(jnp.float32) / sbe, fmt_b)
    # per-group dequant folded into the operands: exact for pow2 scales,
    # so the accumulator sees each partial product rescaled by its own
    # group's sa*sb — eq. 1's structure per 32-element strip
    acc_ref[...] += jnp.dot(aq * sae, bq * sbe,
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _write():
        # the single rounding of the whole per-output-tile ExSdotp chain
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mx_a", "mx_b", "out_dtype",
                     "block_m", "block_n", "block_k", "interpret"))
def mx_gemm_pallas(a: jax.Array, b: jax.Array,
                   sae: jax.Array, sbe: jax.Array, *,
                   mx_a, mx_b=None, out_dtype=jnp.float32,
                   block_m: int = 128, block_n: int = 128,
                   block_k: int = 128,
                   interpret: bool = False) -> jax.Array:
    """C = downcast(sum_k (A/sa→elem)·(B/sb→elem) · sa·sb), fp32 accum.

    ``a[M, K]``/``b[K, N]`` are high-precision operands; ``sae[M, K]``/
    ``sbe[K, N]`` are the per-(row × K-group) / (K-group × column) E8M0
    scales broadcast to element resolution (f32, from
    ``core.scaling.compute_group_scales`` + ``apply_group_scales``-style
    repeat — ``ops.mx_gemm`` prepares them).

    Tile-legality contract (DESIGN.md §8/§14): shapes must be multiples
    of the block sizes and ``block_k`` a multiple of the group
    (``ops.mx_gemm`` pads); on compiled TPU ``block_m`` is a sublane
    8-multiple and ``block_n``/``block_k`` lane 128-multiples
    (interp/CPU CI masks violations).  Group scales are a property of
    the operands, not the tiles, so every legal tile choice accumulates
    the same f32 partials in the same order — bitwise-equal output.
    """
    mx_a = get_mx_format(mx_a)
    mx_b = mx_a if mx_b is None else get_mx_format(mx_b)
    g = mx_a.group
    assert mx_b.group == g, (mx_a, mx_b)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    assert block_k % g == 0, (block_k, g)
    assert sae.shape == a.shape, (sae.shape, a.shape)
    assert sbe.shape == b.shape, (sbe.shape, b.shape)
    grid = (m // block_m, n // block_n, k // block_k)
    kern = functools.partial(_mx_kernel, fmt_a=mx_a.elem, fmt_b=mx_b.elem)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, sae.astype(jnp.float32), sbe.astype(jnp.float32))


# --------------------------------------------------------- packed MX ------
# The storage-resident MX GEMM (DESIGN.md §10): operands arrive as the
# *packed* uint8 payloads ``mx_quant_packed_pallas`` emitted, with their
# E8M0 scale codes.  VMEM holds packed bytes (width/8 B per element);
# the unpack + bit-pattern decode happens in-register, per K-tile, right
# next to the E8M0 dequant — ExSdotp's narrow-in/wide-accumulate
# structure, with HBM and VMEM traffic at the format's true width.
# Scale codes enter at element resolution (``sae8[M, K]`` uint8 — the
# compact [M, K/32] grid would be lane-illegal on compiled TPU, and a
# byte is 4x narrower than the f32 expansion the value-path kernel
# carries).  B's payload is stored transposed ([N, K·w/8]: groups run
# along K down each column), so both operands unpack along their lane
# axis and the MXU contracts their last dims.

def _mx_packed_gemm_kernel(ap_ref, bp_ref, sa8_ref, sb8_ref, o_ref, acc_ref,
                           *, codec_a, codec_b):
    """One (i, j, k) grid step of the packed-ref MX GEMM.

    acc += (decode(A_packed) · sa) @ (decode(B_packed) · sb)^T with the
    per-group pow2 rescale folded into the operands (exact — E8M0), f32
    accumulation across the K grid, single rounding on the last step.
    A 0xFF scale code decodes to NaN and poisons exactly its group's
    contributions — §8's convention, straight from the byte grid.
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # in-register unpack + decode + E8M0 dequant: the packed bytes are
    # the only operand representation VMEM ever holds
    av = codec_a.decode_lanes(ap_ref[...]) * e8m0_decode(sa8_ref[...])
    bv = codec_b.decode_lanes(bp_ref[...]) * e8m0_decode(sb8_ref[...])
    acc_ref[...] += jax.lax.dot_general(
        av, bv, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kk == pl.num_programs(2) - 1)
    def _write():
        # the single rounding of the whole per-output-tile ExSdotp chain
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mx_packed_gemm_db_kernel(ap_hbm, bp_hbm, sa_hbm, sb_hbm, o_ref,
                              ap_s, bp_s, sa_s, sb_s, acc_ref, sems,
                              *, codec_a, codec_b, block_m, block_n,
                              block_k, nk):
    """One (i, j) output tile of the *double-buffered* packed MX GEMM
    (DESIGN.md §14).

    The K loop runs inside the kernel instead of on the grid: the four
    packed operand streams (A/B payloads + E8M0 code grids) stay in HBM
    (``memory_space=ANY``) and are copied tile-by-tile into two VMEM
    slots with explicit async DMAs — the copy for K-tile ``kk+1`` is
    issued *before* the compute for tile ``kk`` waits on its own copy,
    so the HBM→VMEM stream of the next packed tile overlaps the
    unpack/decode/MXU work of the current one.  Compute order, operands
    and the f32 accumulator update are identical to
    ``_mx_packed_gemm_kernel``'s grid pipeline, so the result is
    bitwise equal (tests/test_autotune.py holds it to that).
    """
    i, j = pl.program_id(0), pl.program_id(1)
    bkb_a = codec_a.packed_cols(block_k)
    bkb_b = codec_b.packed_cols(block_k)

    def dmas(slot, kk):
        """The four HBM→VMEM copies landing K-tile ``kk`` in ``slot``."""
        return (
            pltpu.make_async_copy(
                ap_hbm.at[pl.ds(i * block_m, block_m),
                          pl.ds(kk * bkb_a, bkb_a)],
                ap_s.at[slot], sems.at[0, slot]),
            pltpu.make_async_copy(
                bp_hbm.at[pl.ds(j * block_n, block_n),
                          pl.ds(kk * bkb_b, bkb_b)],
                bp_s.at[slot], sems.at[1, slot]),
            pltpu.make_async_copy(
                sa_hbm.at[pl.ds(i * block_m, block_m),
                          pl.ds(kk * block_k, block_k)],
                sa_s.at[slot], sems.at[2, slot]),
            pltpu.make_async_copy(
                sb_hbm.at[pl.ds(j * block_n, block_n),
                          pl.ds(kk * block_k, block_k)],
                sb_s.at[slot], sems.at[3, slot]),
        )

    for d in dmas(0, 0):                       # warm-up: first tile inbound
        d.start()
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(kk, carry):
        cur = jax.lax.rem(kk, 2)
        nxt = jax.lax.rem(kk + 1, 2)

        @pl.when(kk + 1 < nk)
        def _prefetch():                       # overlap: next tile inbound
            for d in dmas(nxt, kk + 1):
                d.start()

        for d in dmas(cur, kk):                # land the current tile
            d.wait()
        # in-register unpack + decode + E8M0 dequant — same fold point,
        # same accumulation order as the grid-pipelined kernel
        av = codec_a.decode_lanes(ap_s[cur]) * e8m0_decode(sa_s[cur])
        bv = codec_b.decode_lanes(bp_s[cur]) * e8m0_decode(sb_s[cur])
        acc_ref[...] += jax.lax.dot_general(
            av, bv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        return carry

    jax.lax.fori_loop(0, nk, body, 0)
    # the single rounding of the whole per-output-tile ExSdotp chain
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mx_a", "mx_b", "out_dtype", "block_m", "block_n",
                     "block_k", "double_buffer", "interpret"))
def mx_gemm_packed_pallas(ap: jax.Array, bp: jax.Array,
                          sae8: jax.Array, sbe8: jax.Array, *,
                          mx_a, mx_b=None, out_dtype=jnp.float32,
                          block_m: int = 128, block_n: int = 128,
                          block_k: int = 512,
                          double_buffer: bool = False,
                          interpret: bool = False) -> jax.Array:
    """C = downcast(sum_k decode(A_p)·sa · (decode(B_p)·sb)^T), fp32 accum.

    ``ap[M, K·wa/8]`` / ``bp[N, K·wb/8]`` are packed uint8 payloads (B
    transposed — its groups run along K); ``sae8[M, K]`` / ``sbe8[N, K]``
    are E8M0 scale codes broadcast to element resolution
    (``ops.mx_gemm_packed`` expands the compact grids and pads).

    Tile-legality contract (DESIGN.md §10/§14): shapes must be
    multiples of the blocks; ``block_m`` is a sublane 8-multiple,
    ``block_n`` a lane 128-multiple, and ``block_k`` a multiple of the
    MX group *and* of both codecs' ``lane_unit`` (FP8 → 128, FP4 → 256,
    FP6 → 512 elements), so every packed K-tile is a 128-multiple byte
    run — the floor the §14 autotuner enumerates candidates above.
    Interp/CPU CI masks lane violations, same as every packed kernel.

    ``double_buffer=True`` swaps the grid-pipelined K loop for the
    in-kernel manual-DMA loop (``_mx_packed_gemm_db_kernel``): two VMEM
    slots per operand stream, the next packed tile's HBM→VMEM copy in
    flight while the current one multiplies.  Bitwise identical output
    (same compute order); it needs ≥ 1 K-tile and pays off when the
    K loop is long enough for the copy/compute overlap to matter.
    """
    mx_a = get_mx_format(mx_a)
    mx_b = mx_a if mx_b is None else get_mx_format(mx_b)
    g = mx_a.group
    assert mx_b.group == g, (mx_a, mx_b)
    ca, cb = get_codec(mx_a), get_codec(mx_b)
    m, k = sae8.shape
    n, k2 = sbe8.shape
    assert k == k2, (sae8.shape, sbe8.shape)
    assert ap.shape == (m, ca.packed_cols(k)), (ap.shape, (m, k))
    assert bp.shape == (n, cb.packed_cols(k)), (bp.shape, (n, k))
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    assert block_k % g == 0, (block_k, g)
    assert block_k % ca.lane_unit == 0 and block_k % cb.lane_unit == 0, (
        block_k, ca.lane_unit, cb.lane_unit)
    grid = (m // block_m, n // block_n, k // block_k)
    bkb_a = ca.packed_cols(block_k)
    bkb_b = cb.packed_cols(block_k)
    if double_buffer:
        nk = k // block_k
        kern = functools.partial(
            _mx_packed_gemm_db_kernel, codec_a=ca, codec_b=cb,
            block_m=block_m, block_n=block_n, block_k=block_k, nk=nk)
        return pl.pallas_call(
            kern,
            grid=(m // block_m, n // block_n),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * 4,
            out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            scratch_shapes=[
                pltpu.VMEM((2, block_m, bkb_a), jnp.uint8),
                pltpu.VMEM((2, block_n, bkb_b), jnp.uint8),
                pltpu.VMEM((2, block_m, block_k), jnp.uint8),
                pltpu.VMEM((2, block_n, block_k), jnp.uint8),
                pltpu.VMEM((block_m, block_n), jnp.float32),
                pltpu.SemaphoreType.DMA((4, 2)),
            ],
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel")),
            interpret=interpret,
        )(ap, bp, sae8, sbe8)
    kern = functools.partial(_mx_packed_gemm_kernel, codec_a=ca, codec_b=cb)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, bkb_a), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, bkb_b), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ap, bp, sae8, sbe8)
