"""Fused block-scaled ExSdotp GEMM — Pallas TPU kernel (DESIGN.md §3).

The per-tensor pipeline costs an extra HBM round-trip: quantize writes
``q`` (and re-reads ``x``), then the GEMM reads ``q`` again.  Here the
cast happens *inside* the GEMM kernel: high-precision (fp32/bf16) tiles
stream HBM→VMEM once, are divided by their per-block scale and cast to
the minifloat format in VMEM, multiplied on the MXU, and the partial
product is rescaled by ``sa * sb`` into the fp32 accumulator.  The
quantized tensor never exists in HBM.

Scales are precomputed per (row-tile × K-tile) by
``core.scaling.compute_block_scales`` — a tiny reduce, grid-mapped into
SMEM so each (i, j, k) step reads exactly the two scalars it needs.
Because the rescale is applied at *accumulator granularity* (once per
K-tile partial product, inside the fp32 accumulator), the ExSdotp
structure of eq. 1 is preserved per block: multiply narrow, accumulate
wide across the whole K loop, round once on the final write.

With pow2 scales (the default) the divide and the rescale are exact, so
the only rounding anywhere is (a) the mantissa cast into the minifloat
format and (b) the single final downcast — the same two roundings the
paper's hardware performs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.formats import _quantize_f32, get_mx_format
from ._compat import CompilerParams

__all__ = ["blockscale_gemm_pallas", "mx_gemm_pallas"]


def _kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref,
            *, q_dtype_a, q_dtype_b):
    """One (i, j, k) grid step of the fused quantize+GEMM.

    acc += dequant(cast(A_ik / sa), cast(B_kj / sb)) with the per-block
    rescale ``sa * sb`` folded into the accumulator update; single
    rounding into ``o_ref.dtype`` on the last K step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sa = sa_ref[0, 0]
    sb = sb_ref[0, 0]
    # quantize in VMEM: one scale per (block_m, block_k) / (block_k,
    # block_n) tile — the CAST unit fused into the GEMM's stream
    aq = (a_ref[...].astype(jnp.float32) / sa).astype(q_dtype_a)
    bq = (b_ref[...].astype(jnp.float32) / sb).astype(q_dtype_b)
    # expanding multiply + per-block dequant at accumulator granularity
    acc_ref[...] += jnp.dot(
        aq.astype(jnp.float32), bq.astype(jnp.float32),
        preferred_element_type=jnp.float32) * (sa * sb)

    @pl.when(k == pl.num_programs(2) - 1)
    def _write():
        # the single rounding of the whole per-output-tile ExSdotp chain
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("q_dtype_a", "q_dtype_b", "out_dtype",
                     "block_m", "block_n", "block_k", "interpret"))
def blockscale_gemm_pallas(a: jax.Array, b: jax.Array,
                           sa: jax.Array, sb: jax.Array, *,
                           q_dtype_a, q_dtype_b, out_dtype=jnp.float32,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """C = downcast(sum_k (A_ik/sa→q)·(B_kj/sb→q) · sa·sb), fp32 accum.

    ``a[M, K]``/``b[K, N]`` are high-precision (fp32/bf16) operands;
    ``sa[M/bm, K/bk]``/``sb[K/bk, N/bn]`` are per-block dequant scales
    (f32, from ``core.scaling.compute_block_scales``).  Shapes must be
    multiples of the block sizes (``ops.py`` pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    assert sa.shape == (m // block_m, k // block_k), sa.shape
    assert sb.shape == (k // block_k, n // block_n), sb.shape
    grid = (m // block_m, n // block_n, k // block_k)
    kern = functools.partial(_kernel, q_dtype_a=jnp.dtype(q_dtype_a),
                             q_dtype_b=jnp.dtype(q_dtype_b))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, sa.astype(jnp.float32), sb.astype(jnp.float32))


# ----------------------------------------------------------------- MX ------
# Same fused structure at MX granularity (DESIGN.md §8).  Scales enter
# at *element resolution* (sae[M, K], sbe[K, N] — each group's scale
# pre-broadcast over its 32 elements): compact (M, K/32) grids would put
# a 4-lane axis on the scale tiles, which compiled TPU Pallas rejects
# (lane dims must be 128-multiples — the blockscale_blocks rule; masked
# on CPU CI).  The f32 expansion costs emulation-path bandwidth only; a
# production kernel would carry packed E8M0 bytes.  Because E8M0 scales
# are powers of two, multiplying the *elements* by their group scale
# before the MXU dot is bit-identical to rescaling each group's partial
# product after it: per-group dequant at accumulator granularity with no
# per-group inner loop.

def _mx_kernel(a_ref, b_ref, sae_ref, sbe_ref, o_ref, acc_ref,
               *, fmt_a, fmt_b):
    """One (i, j, k) grid step of the fused MX quantize+GEMM.

    acc += dequant(cast(A/sa), cast(B/sb)) with each element carrying its
    own group's exact pow2 rescale into the f32 accumulator; a NaN (E8M0
    0xFF) group scale poisons exactly that group's contributions.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sae = sae_ref[...]
    sbe = sbe_ref[...]
    # quantize in VMEM: value-space element cast (bit-identical to the
    # native cast where one exists; FP6/FP4 have none)
    aq = _quantize_f32(a_ref[...].astype(jnp.float32) / sae, fmt_a)
    bq = _quantize_f32(b_ref[...].astype(jnp.float32) / sbe, fmt_b)
    # per-group dequant folded into the operands: exact for pow2 scales,
    # so the accumulator sees each partial product rescaled by its own
    # group's sa*sb — eq. 1's structure per 32-element strip
    acc_ref[...] += jnp.dot(aq * sae, bq * sbe,
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _write():
        # the single rounding of the whole per-output-tile ExSdotp chain
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mx_a", "mx_b", "out_dtype",
                     "block_m", "block_n", "block_k", "interpret"))
def mx_gemm_pallas(a: jax.Array, b: jax.Array,
                   sae: jax.Array, sbe: jax.Array, *,
                   mx_a, mx_b=None, out_dtype=jnp.float32,
                   block_m: int = 128, block_n: int = 128,
                   block_k: int = 128,
                   interpret: bool = False) -> jax.Array:
    """C = downcast(sum_k (A/sa→elem)·(B/sb→elem) · sa·sb), fp32 accum.

    ``a[M, K]``/``b[K, N]`` are high-precision operands; ``sae[M, K]``/
    ``sbe[K, N]`` are the per-(row × K-group) / (K-group × column) E8M0
    scales broadcast to element resolution (f32, from
    ``core.scaling.compute_group_scales`` + ``apply_group_scales``-style
    repeat — ``ops.mx_gemm`` prepares them).  Shapes must be multiples
    of the block sizes and ``block_k`` a multiple of the group
    (``ops.mx_gemm`` pads).
    """
    mx_a = get_mx_format(mx_a)
    mx_b = mx_a if mx_b is None else get_mx_format(mx_b)
    g = mx_a.group
    assert mx_b.group == g, (mx_a, mx_b)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    assert block_k % g == 0, (block_k, g)
    assert sae.shape == a.shape, (sae.shape, a.shape)
    assert sbe.shape == b.shape, (sbe.shape, b.shape)
    grid = (m // block_m, n // block_n, k // block_k)
    kern = functools.partial(_mx_kernel, fmt_a=mx_a.elem, fmt_b=mx_b.elem)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, sae.astype(jnp.float32), sbe.astype(jnp.float32))
