"""Minifloat quantization kernels — the CAST unit of the extended FPU.

Three granularities:

* per-tensor: one scale for the whole tensor (classic FP8 recipes; the
  amax reduce runs in XLA, the cast is trivially fused by XLA too);
* per-block (Pallas): each (bm, bn) tile computes its own amax, scale and
  cast in one VMEM pass — a beyond-paper optimization matching how modern
  FP8 training (e.g. 128x128 block scaling) bounds quantization error, and
  the natural granularity for the ExSdotp GEMM's tiles;
* per-group MX (Pallas): groups of 32 consecutive elements along the last
  (contraction) axis share one E8M0 power-of-two scale (DESIGN.md §8) —
  amax, pow2 scale and the value-space element cast all fused in VMEM.

The kernels fuse amax + scale + cast so the tensor is read once from HBM
and written once at a fraction of the bytes: a pure memory-roofline win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.formats import _quantize_f32, e8m0_encode, get_mx_format
from ..core.scaling import compute_group_scales, expand_group_scales
from ._compat import CompilerParams
from .codec import get_codec

__all__ = ["quant_blockwise_pallas", "mx_quant_pallas",
           "mx_quant_packed_pallas"]


def _kernel(x_ref, q_ref, s_ref, *, max_normal: float, margin: float):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    # dequant scale s: quantized = x / s fills the format's range.
    # Non-finite amax -> scale 1 so inf/NaN propagate to the output
    # instead of an inf scale flushing the whole tile to zero.
    s = jnp.where((amax > 0) & jnp.isfinite(amax),
                  amax / (max_normal * margin), 1.0)
    q_ref[...] = (x / s).astype(q_ref.dtype)
    s_ref[0, 0] = s


@functools.partial(
    jax.jit,
    static_argnames=("q_dtype", "block_m", "block_n", "margin", "interpret"))
def quant_blockwise_pallas(x: jax.Array, *, q_dtype,
                           block_m: int = 128, block_n: int = 128,
                           margin: float = 1.0,
                           interpret: bool = False):
    """Quantize x[M,N] into ``q_dtype`` with one scale per (bm, bn) block.

    Returns (q[M,N], scales[ceil(M/bm), ceil(N/bn)]) with
    x ~= q.astype(f32) * scale broadcast per block.  Non-multiple shapes
    are zero-padded up to block multiples (exact for amax — zeros never
    raise it — and sliced back off the payload; fully-padded blocks get
    the neutral scale 1).  ``margin`` < 1 reserves headroom below
    max_normal.

    Tile-legality contract (DESIGN.md §3/§14): ``block_m`` is a sublane
    8-multiple, ``block_n`` a lane 128-multiple on compiled TPU
    (interp/CPU CI masks violations).  Blocks ARE the scale granularity
    here — changing them changes the quantization, so the §14 autotuner
    never sweeps this kernel's blocks (see ``blockscale_gemm_pallas``'s
    ``scale_block_*`` for how the GEMM side keeps the grid fixed).
    """
    m, n = x.shape
    pm, pn = (-m) % block_m, (-n) % block_n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    mp, np_ = x.shape
    grid = (mp // block_m, np_ // block_n)
    max_normal = float(jnp.finfo(q_dtype).max)
    kern = functools.partial(_kernel, max_normal=max_normal, margin=margin)
    q, s = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), q_dtype),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x)
    return q[:m, :n], s


# --------------------------------------------------------------- MX path --

def _mx_kernel(x_ref, q_ref, se_ref, *, fmt, group: int):
    """Fused MX group quantize for one (bm, bk) tile.

    Per 1×group strip: amax -> E8M0 pow2 scale (non-finite -> NaN scale,
    zero -> neutral 1, via ``compute_group_scales`` — the single source
    of the E8M0 formula) -> exact pow2 divide -> value-space element
    cast (`_quantize_f32`, bit-identical to a native cast where one
    exists).  The scale output is written at *element resolution*
    (``se[bm, bk]``): a compact ``(bm, bk//32)`` tile would put a
    4-lane axis on the output — illegal on compiled TPU Pallas (lane
    dims must be 128-multiples; masked on CPU CI) — so the wrapper
    compacts with a strided slice instead.
    """
    x = x_ref[...].astype(jnp.float32)
    bm, bk = x.shape
    s = compute_group_scales(x, group, fmt.max_normal)
    se = expand_group_scales(s, group).reshape(bm, bk)
    q_ref[...] = _quantize_f32(x / se, fmt)
    se_ref[...] = se


@functools.partial(
    jax.jit,
    static_argnames=("mx", "block_m", "block_k", "interpret"))
def mx_quant_pallas(x: jax.Array, *, mx, block_m: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """Quantize ``x[M, K]`` into the MX format ``mx`` (name or MXFormat).

    Returns ``(q[M, K] f32, scales[M, K/group] f32)``: ``q`` holds the
    element-format values of ``x / s`` (value-space emulation — FP6/FP4
    have no native jnp dtype, so the payload stays f32 on the emulation
    path) and ``s`` the per-(row × group) E8M0 scales.

    Tile-legality contract (DESIGN.md §8/§14): shapes must be multiples
    of the blocks (``ops.mx_quantize`` pads); ``block_k`` must contain
    whole groups, and on compiled TPU ``block_m`` is a sublane
    8-multiple / ``block_k`` a lane 128-multiple (interp/CPU CI masks
    violations).  Scales are per group-of-32 regardless of the tiles,
    so any legal block choice quantizes identically.
    """
    mx = get_mx_format(mx)
    m, k = x.shape
    assert m % block_m == 0 and k % block_k == 0, ((m, k), (block_m, block_k))
    assert block_k % mx.group == 0, (block_k, mx.group)
    grid = (m // block_m, k // block_k)
    kern = functools.partial(_mx_kernel, fmt=mx.elem, group=mx.group)
    q, se = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_k), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m, k), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x)
    # compact the element-resolution scales back to one per group
    return q, se[:, ::mx.group]


# ----------------------------------------------------- packed MX path --

def _mx_packed_kernel(x_ref, p_ref, s8_ref, *, codec, group: int):
    """Fused packed MX quantize for one (bm, bk) tile (DESIGN.md §10).

    Same group amax → E8M0 pow2 scale → exact pow2 divide pipeline as
    ``_mx_kernel``, but the element cast lands straight in *packed*
    uint8 storage: ``codec.encode_lanes`` quantizes, extracts the bit
    patterns and packs them into dense lanes in-register, so the
    payload leaves VMEM at ``width/8`` bytes per element — no byte- or
    f32-wide quantized intermediate ever reaches HBM.  Scales are
    written as E8M0 *codes* at element resolution (``s8[bm, bk]``
    uint8; one byte instead of the f32 path's four) for the same
    lane-legality reason as ``_mx_kernel``: a compact ``(bm, bk//32)``
    output tile would be lane-illegal on compiled TPU.  A non-finite
    group encodes scale 0xFF (NaN) and a max-magnitude payload pattern
    — the §8 poison convention, byte-level.
    """
    x = x_ref[...].astype(jnp.float32)
    bm, bk = x.shape
    s = compute_group_scales(x, group, codec.fmt.max_normal)
    se = expand_group_scales(s, group).reshape(bm, bk)
    s8_ref[...] = e8m0_encode(se)
    p_ref[...] = codec.encode_lanes(x / se)


@functools.partial(
    jax.jit,
    static_argnames=("mx", "block_m", "block_k", "interpret"))
def mx_quant_packed_pallas(x: jax.Array, *, mx, block_m: int = 128,
                           block_k: int = 512, interpret: bool = False):
    """Quantize ``x[M, K]`` into *packed* MX storage (DESIGN.md §10).

    Returns ``(payload[M, K·w/8] u8, s8[M, K/group] u8)``: the densely
    packed element bit patterns and the E8M0 scale codes — the honest
    HBM footprint, emitted directly by the kernel.

    Tile-legality contract (DESIGN.md §10/§14): shapes must be
    multiples of the blocks (``ops.mx_quantize`` pads); ``block_k``
    must be a multiple of the group *and* of the codec's ``lane_unit``
    (packed byte runs must be legal 128-multiple lane tiles on compiled
    TPU — FP8: 128, FP4: 256, FP6: 512 elements; masked on CPU CI).
    Group scales are tile-independent, so any legal block choice packs
    identical bytes.
    """
    mx = get_mx_format(mx)
    codec = get_codec(mx)
    m, k = x.shape
    assert m % block_m == 0 and k % block_k == 0, ((m, k), (block_m, block_k))
    assert block_k % mx.group == 0, (block_k, mx.group)
    assert block_k % codec.lane_unit == 0, (block_k, codec.lane_unit)
    grid = (m // block_m, k // block_k)
    bkb = codec.packed_cols(block_k)
    kern = functools.partial(_mx_packed_kernel, codec=codec, group=mx.group)
    p, s8 = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_k), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_m, bkb), lambda i, j: (i, j)),
            pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, codec.packed_cols(k)), jnp.uint8),
            jax.ShapeDtypeStruct((m, k), jnp.uint8),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x)
    # compact the element-resolution scale codes back to one per group
    return p, s8[:, ::mx.group]
