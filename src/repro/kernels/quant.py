"""Minifloat quantization kernels — the CAST unit of the extended FPU.

Two granularities:

* per-tensor: one scale for the whole tensor (classic FP8 recipes; the
  amax reduce runs in XLA, the cast is trivially fused by XLA too);
* per-block (Pallas): each (bm, bn) tile computes its own amax, scale and
  cast in one VMEM pass — a beyond-paper optimization matching how modern
  FP8 training (e.g. 128x128 block scaling) bounds quantization error, and
  the natural granularity for the ExSdotp GEMM's tiles.

The kernel fuses amax + scale + cast so the tensor is read once from HBM
and written once at 1/4-1/2 the bytes: a pure memory-roofline win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["quant_blockwise_pallas"]


def _kernel(x_ref, q_ref, s_ref, *, max_normal: float, margin: float):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    # dequant scale s: quantized = x / s fills the format's range.
    # Non-finite amax -> scale 1 so inf/NaN propagate to the output
    # instead of an inf scale flushing the whole tile to zero.
    s = jnp.where((amax > 0) & jnp.isfinite(amax),
                  amax / (max_normal * margin), 1.0)
    q_ref[...] = (x / s).astype(q_ref.dtype)
    s_ref[0, 0] = s


@functools.partial(
    jax.jit,
    static_argnames=("q_dtype", "block_m", "block_n", "margin", "interpret"))
def quant_blockwise_pallas(x: jax.Array, *, q_dtype,
                           block_m: int = 128, block_n: int = 128,
                           margin: float = 1.0,
                           interpret: bool = False):
    """Quantize x[M,N] into ``q_dtype`` with one scale per (bm, bn) block.

    Returns (q[M,N], scales[M/bm, N/bn]) with x ~= q.astype(f32) * scale
    broadcast per block. ``margin`` < 1 reserves headroom below max_normal.
    """
    m, n = x.shape
    assert m % block_m == 0 and n % block_n == 0, ((m, n), (block_m, block_n))
    grid = (m // block_m, n // block_n)
    max_normal = float(jnp.finfo(q_dtype).max)
    kern = functools.partial(_kernel, max_normal=max_normal, margin=margin)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_n), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), q_dtype),
            jax.ShapeDtypeStruct((m // block_m, n // block_n), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x)
