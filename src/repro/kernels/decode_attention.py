"""Decode attention over the serving KV cache — Pallas TPU kernels
(DESIGN.md §12).

Serving attends S new query rows (S=1 steady-state decode, S=prompt
for batched prefill) against a cache of T slots of which only a
per-sequence prefix ``lens + S`` is live: slots ``0..lens-1`` hold the
history, ``lens..lens+S-1`` the rows being computed, and everything
beyond is garbage (unwritten, or stale payloads from a freed page).
Both kernels reuse the flash-attention shell (``_kernel``/``_call``)
with two decode-specific twists threaded through the shared
online-softmax core:

* **base offset** — the per-sequence length enters as a scalar operand
  (``[BH, 1]`` int32, one per batch·head row); q row ``i`` sits at
  absolute cache slot ``base + i``, so the causal mask is
  ``col <= base + row`` and the carry-skip condition gains ``+ base``
  — with a dynamic base the skip doubles as a *page-skip*: KV tiles
  past a short sequence's live prefix never execute.
* **garbage masking** — the loader zeroes key slots at index
  ``>= base + S`` *structurally* (before any dot), so non-finite trash
  in dead cache slots — e.g. NaN-scale poison left by a retired
  sequence whose pages were re-used — cannot leak into live rows via
  ``0 · NaN``.  Poison *inside* the live prefix still propagates
  (0xFF scale codes decode NaN), exactly like the train-path kernels.

``mx_decode_attention_pallas`` streams the cache as *packed* codec
payloads + E8M0 scale codes and decodes groups in-register beside the
f32 (m, l, acc) accumulators — the same ``codec.decode_lanes`` fold
point as ``mx_flash_attention_pallas``.  ``decode_attention_pallas``
is the carrier-precision variant (the bf16 page-pool fallback).

Compiled-TPU lane legality follows the §11 convention: packed payload
rows must be 128-byte multiples and S=1 gives a sublane-short q tile —
interp/CPU CI masks violations; real-TPU serving pads the head axis at
the layer above.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.formats import e8m0_decode, get_mx_format
from .codec import get_codec
from .flash_attention import _call, _kernel

__all__ = ["decode_attention_pallas", "mx_decode_attention_pallas"]


def _lens2d(lens, bh):
    lens = jnp.asarray(lens, jnp.int32)
    assert lens.shape == (bh,), (lens.shape, bh)
    return lens.reshape(bh, 1)


def _mask_garbage(k, v, kk, limit, block_k):
    """Zero key/value slots at cache index >= limit (structural
    exclusion of dead slots — not via softmax weights, which would turn
    stale NaN into NaN·0)."""
    idx = kk * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (k.shape[0], 1), 0)
    good = idx < limit
    return jnp.where(good, k, 0.0), jnp.where(good, v, 0.0)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "skip_masked", "debug_visited",
                     "interpret"))
def decode_attention_pallas(q, k, v, lens, *, block_q: int = 8,
                            block_k: int = 128, skip_masked: bool = True,
                            debug_visited: bool = False,
                            interpret: bool = False):
    """q [BH, S, hd], k/v [BH, T, hd], lens [BH] -> [BH, S, hd].

    The serving sweep over a carrier-precision cache (DESIGN.md §12).
    q row ``i`` of sequence-head ``b`` attends cache slots
    ``0..lens[b]+i``; slots beyond ``lens[b]+S`` are treated as garbage
    and excluded structurally.  ``debug_visited=True`` additionally
    returns the int32 [BH, S/bq, T/bk] visit grid (page-skip tests).

    Tile-legality contract (DESIGN.md §12/§14): ``block_q`` | S and
    ``block_k`` | T exactly (positional mask — assert, don't pad).  The
    decode q axis may fall below the sublane unit, down to ``block_q=1``
    (S=1 steady-state decode) — interpret/CPU-only below 8; real-TPU
    serving picks aligned page sizes (``ops.decode_attention_blocks`` /
    the §14 autotuner, floors 1 and 8).
    """
    bh, s, hd = q.shape
    t = k.shape[1]
    assert s % block_q == 0 and t % block_k == 0, ((s, t),
                                                   (block_q, block_k))

    def load_kv(refs):
        lens_ref, k_ref, v_ref = refs[0], refs[1], refs[2]
        base = lens_ref[0, 0]

        def loader(kk, limit):
            return _mask_garbage(k_ref[0].astype(jnp.float32),
                                 v_ref[0].astype(jnp.float32),
                                 kk, limit, block_k)

        return loader, base, refs[3:]

    kern = functools.partial(
        _kernel, load_kv=load_kv, causal=True, scale=hd ** -0.5,
        block_q=block_q, block_k=block_k, skip_masked=skip_masked,
        debug_visited=debug_visited)
    specs = [pl.BlockSpec((1, 1), lambda b, i, kk: (b, 0)),
             pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0)),
             pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0))]
    return _call(kern, q, (_lens2d(lens, bh), k, v), specs,
                 block_q=block_q, block_k=block_k, t=t,
                 debug_visited=debug_visited, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("mx_k", "mx_v", "block_q", "block_k", "skip_masked",
                     "debug_visited", "interpret"))
def mx_decode_attention_pallas(q, kp, ks8, vp, vs8, lens, *, mx_k,
                               mx_v=None, block_q: int = 8,
                               block_k: int = 128,
                               skip_masked: bool = True,
                               debug_visited: bool = False,
                               interpret: bool = False):
    """Decode attention straight from the packed paged KV cache
    (DESIGN.md §12).

    ``q [BH, S, hd]`` carrier precision; ``(kp, ks8)`` / ``(vp, vs8)``
    are the gathered page slots in ``ops.mx_quantize_kv`` layout:
    payload ``[BH, T, hd·w/8]`` uint8 + E8M0 codes ``[BH, T, hd/group]``
    (group scales along the head dimension); ``lens [BH]`` int32 live
    lengths.  Tiles stream packed from HBM and decode in-register; a
    0xFF scale code inside the live prefix decodes NaN and poisons
    exactly the rows that attend to it, while garbage slots beyond
    ``lens + S`` are structurally zeroed before the dots.

    Bit-exact vs ``ref.mx_decode_attention_ref`` on exact-arithmetic
    operands (``tests/fuzz.exact_decode_operands``) — the same bar as
    every codec kernel.

    Tile-legality contract: as ``decode_attention_pallas`` (§12/§14 —
    tiles divide S/T exactly, ``block_q`` down to 1 interp-only), plus
    hd a whole number of groups so the packed byte run is lane-legal.
    """
    mx_k = get_mx_format(mx_k)
    mx_v = mx_k if mx_v is None else get_mx_format(mx_v)
    ck, cv = get_codec(mx_k), get_codec(mx_v)
    g = mx_k.group
    assert mx_v.group == g, (mx_k.name, mx_v.name)
    bh, s, hd = q.shape
    t = kp.shape[1]
    assert s % block_q == 0 and t % block_k == 0, ((s, t),
                                                   (block_q, block_k))
    assert hd % g == 0, (hd, g)
    assert kp.shape == (bh, t, ck.packed_cols(hd)), (kp.shape, (bh, t, hd))
    assert vp.shape == (bh, t, cv.packed_cols(hd)), (vp.shape, (bh, t, hd))
    assert ks8.shape == vs8.shape == (bh, t, hd // g), (ks8.shape, vs8.shape)
    # scale codes at element resolution (compact grids are lane-illegal
    # on compiled TPU — the §8 rule)
    ks8e = jnp.repeat(ks8, g, axis=-1)
    vs8e = jnp.repeat(vs8, g, axis=-1)

    def load_kv(refs):
        lens_ref = refs[0]
        kp_ref, ks_ref, vp_ref, vs_ref = refs[1:5]
        base = lens_ref[0, 0]

        def loader(kk, limit):
            k = ck.decode_lanes(kp_ref[0]) * e8m0_decode(ks_ref[0])
            v = cv.decode_lanes(vp_ref[0]) * e8m0_decode(vs_ref[0])
            return _mask_garbage(k, v, kk, limit, block_k)

        return loader, base, refs[5:]

    kern = functools.partial(
        _kernel, load_kv=load_kv, causal=True, scale=hd ** -0.5,
        block_q=block_q, block_k=block_k, skip_masked=skip_masked,
        debug_visited=debug_visited)
    pk, pv = ck.packed_cols(hd), cv.packed_cols(hd)
    specs = [pl.BlockSpec((1, 1), lambda b, i, kk: (b, 0)),
             pl.BlockSpec((1, block_k, pk), lambda b, i, kk: (b, kk, 0)),
             pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0)),
             pl.BlockSpec((1, block_k, pv), lambda b, i, kk: (b, kk, 0)),
             pl.BlockSpec((1, block_k, hd), lambda b, i, kk: (b, kk, 0))]
    return _call(kern, q, (_lens2d(lens, bh), kp, ks8e, vp, vs8e), specs,
                 block_q=block_q, block_k=block_k, t=t,
                 debug_visited=debug_visited, interpret=interpret)
