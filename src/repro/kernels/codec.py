"""Payload codecs — one narrow-storage layer from HBM to the MACs
(DESIGN.md §10).

A ``PayloadCodec`` describes, for one element format, everything the
rest of the stack needs to keep payloads *packed* end to end:

* the storage dtype (always uint8 lanes) and the packed-shape math
  (``packed_cols`` / ``logical_cols`` / ``pack_align``);
* the compiled-TPU lane-legality unit (``lane_unit``): the smallest
  K-tile, in elements, whose packed byte run is a 128-multiple — the
  tile floor every packed Pallas ref must respect;
* the codec itself, implemented twice and cross-tested bit for bit:
  a numpy oracle (``encode_pack_np`` / ``unpack_decode_np``, built on
  ``core.formats.encode_np``/``decode_np`` + ``kernels.pack``'s layout
  oracles) and **Pallas-inlinable lane ops** (``encode_lanes`` /
  ``decode_lanes`` / ``pack_lanes`` / ``unpack_lanes``) — pure jnp
  shifts/masks/bitcasts with no data-dependent shapes, so the same
  functions run at the XLA level *and* inside Pallas kernel bodies,
  where they are the in-register unpack/decode sitting next to the
  E8M0 dequant (ExSdotp's narrow-in / wide-accumulate structure).

This is the single place the packed layout is interpreted: the packed
quantize kernel (``kernels/quant.py``), the packed GEMM kernel
(``kernels/blockscale_gemm.py``), the storage wrappers
(``kernels/ops.py``) and the TP wire (``parallel/tp_gemm.py``) all
route through a codec instead of open-coding pack/encode calls, so a
future format (INT4 groups, two-level scales) lands as one codec + one
policy entry rather than another kernel fork.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from ..core import formats as F
from . import pack as packlib

__all__ = ["PayloadCodec", "get_codec"]


@dataclasses.dataclass(frozen=True)
class PayloadCodec:
    """Bit-pattern codec for one :class:`~repro.core.formats.MiniFloatFormat`.

    Hashable (frozen over a frozen format), so it can close over Pallas
    kernels and ride jit static arguments.
    """

    fmt: F.MiniFloatFormat

    # ---- shape math --------------------------------------------------
    @property
    def width(self) -> int:
        return self.fmt.width

    @property
    def pack_align(self) -> int:
        """Element-count multiple a packed run must be (FP4: 2, FP6: 4,
        byte-wide: 1) — one "word" of the packed stream."""
        return self.fmt.pack_align

    @property
    def word_bytes(self) -> int:
        """Bytes per packed word (FP4: 1, FP6: 3, FP8: 1)."""
        return self.pack_align * self.width // 8

    @property
    def elems_per_word(self) -> int:
        return self.pack_align

    @property
    def storage_dtype(self):
        """Packed payloads are always dense uint8 lanes."""
        return jnp.dtype(jnp.uint8)

    @property
    def lane_unit(self) -> int:
        """Smallest K-tile (in elements) whose packed byte run is a legal
        compiled-TPU lane tile: ``unit * width / 8`` must be a multiple
        of 128 (FP8 → 128, FP4 → 256, FP6 → 512).  Interp/CPU CI masks
        violations — same convention as ``ops.blockscale_blocks``."""
        return 8 * 128 // math.gcd(self.width, 8)

    def packed_cols(self, k: int) -> int:
        """Bytes holding ``k`` codes (``k`` must be pack-aligned)."""
        assert k % self.pack_align == 0, (k, self.pack_align)
        return k * self.width // 8

    def logical_cols(self, nbytes: int) -> int:
        """Elements held by ``nbytes`` packed bytes."""
        assert (nbytes * 8) % self.width == 0, (nbytes, self.width)
        return nbytes * 8 // self.width

    def pad_cols(self, k: int) -> int:
        """``k`` rounded up to the pack alignment."""
        return k + (-k) % self.pack_align

    # ---- numpy oracle ------------------------------------------------
    def encode_pack_np(self, values: np.ndarray) -> np.ndarray:
        """Values → fmt bit patterns → densely packed uint8 bytes."""
        codes = F.encode_np(values, self.fmt).astype(np.uint8)
        return packlib.pack_codes_np(codes, self.width)

    def unpack_decode_np(self, payload: np.ndarray) -> np.ndarray:
        """Packed uint8 bytes → fmt bit patterns → float values."""
        codes = packlib.unpack_codes_np(payload, self.width)
        return F.decode_np(codes, self.fmt)

    # ---- Pallas-inlinable lane ops (also jit-safe at the XLA level) --
    def pack_lanes(self, codes: jax.Array) -> jax.Array:
        """uint8 codes ``[..., K]`` → packed bytes ``[..., K·w/8]``."""
        return packlib.pack_codes(codes, self.width)

    def unpack_lanes(self, payload: jax.Array) -> jax.Array:
        """Packed bytes ``[..., B]`` → uint8 codes ``[..., 8B/w]``."""
        return packlib.unpack_codes(payload, self.width)

    def encode_lanes(self, values: jax.Array) -> jax.Array:
        """f32 values ``[..., K]`` → packed bytes ``[..., K·w/8]``.

        Quantizes to the representable set first (idempotent on already
        representable values), so it is safe directly on ``x / s``
        inside the fused quantize kernel.  Bit-identical to
        ``encode_pack_np``."""
        return self.pack_lanes(F.encode(values, self.fmt))

    def decode_lanes(self, payload: jax.Array) -> jax.Array:
        """Packed bytes → f32 values; exact inverse of ``encode_lanes``
        for every representable value.  Bit-identical to
        ``unpack_decode_np``."""
        return F.decode(self.unpack_lanes(payload), self.fmt)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return (f"codec({self.fmt.name}: {self.elems_per_word} elems / "
                f"{self.word_bytes} B)")


_CODECS: dict[str, PayloadCodec] = {}


def get_codec(fmt) -> PayloadCodec:
    """Codec for a format / MX format / name (width ≤ 8 — the packable
    set); instances are cached so identity works as a jit static arg."""
    if isinstance(fmt, PayloadCodec):
        return fmt
    if isinstance(fmt, F.MXFormat):
        fmt = fmt.elem
    fmt = F.get_format(fmt)
    assert fmt.width <= 8, f"no packed codec for {fmt}"
    c = _CODECS.get(fmt.name)
    if c is None:
        c = _CODECS[fmt.name] = PayloadCodec(fmt)
    return c
