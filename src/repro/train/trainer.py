"""Trainer — the host-side loop: data, checkpoints, restart, stragglers.

Fault-tolerance model (designed for 1000+ nodes, exercised in tests on 1):

* **checkpoint/restart**: atomic async checkpoints every ``save_every``
  steps; on construction the trainer auto-resumes from LATEST. A crash
  (or induced failure — ``fail_at_step`` hook in tests) loses at most the
  steps since the last save; data is hash-addressed so resume is
  bit-exact.
* **straggler mitigation**: per-step wall time is tracked against a
  running median; steps slower than ``straggler_factor``x are counted and
  surfaced in metrics — at fleet scale this signal drives hot-spare swaps;
  here it additionally triggers an optional callback.
* **elastic re-scale**: state is saved device-layout-free; ``restore``
  re-shards onto whatever mesh is current (see checkpoint/ckpt.py), so a
  512-chip job restarts on 256 chips by just rebuilding the mesh.
* **numeric faults**: non-finite grads skip the update (train_step),
  so a single bad batch/node cannot poison the weights.
"""
from __future__ import annotations

import time
from statistics import median
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..checkpoint.ckpt import CheckpointManager
from ..data.pipeline import DataConfig, SyntheticTokens

__all__ = ["Trainer"]


class Trainer:
    def __init__(self, model, train_step, state, data: SyntheticTokens,
                 *, ckpt_dir: str, save_every: int = 50,
                 shardings: Any = None, straggler_factor: float = 3.0,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 fail_at_step: Optional[int] = None):
        self.model = model
        self.train_step = jax.jit(train_step, donate_argnums=(0,)) if not (
            hasattr(train_step, "lower")) else train_step
        self.data = data
        self.ckpt = CheckpointManager(ckpt_dir)
        self.save_every = save_every
        self.shardings = shardings
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler
        self.fail_at_step = fail_at_step
        self.step_times: list[float] = []
        self.straggler_count = 0
        self.metrics_log: list[dict] = []

        latest = self.ckpt.latest_step()
        if latest is not None:
            state = self.ckpt.restore(latest, state, shardings)
            self.start_step = latest
        else:
            self.start_step = 0
        self.state = state

    def run(self, num_steps: int, aux_fn: Optional[Callable] = None):
        try:
            return self._run(num_steps, aux_fn)
        finally:
            # flush any in-flight async checkpoint even when a step raises:
            # the atomic publish (rename + LATEST) then reflects the most
            # recent completed save, which is what restart resumes from.
            self.ckpt.wait()

    def _run(self, num_steps: int, aux_fn: Optional[Callable] = None):
        for step in range(self.start_step, self.start_step + num_steps):
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"induced failure at step {step}")
            batch = self.data.global_batch_at_step(step)
            aux = aux_fn(step) if aux_fn else None
            t0 = time.perf_counter()
            if aux is not None:
                self.state, metrics = self.train_step(self.state,
                                                      batch, aux)
            else:
                self.state, metrics = self.train_step(self.state, batch)
            jax.block_until_ready(jax.tree.leaves(metrics)[0])
            dt = time.perf_counter() - t0
            self._track_straggler(step, dt)
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            metrics["step"] = step
            metrics["step_time_s"] = dt
            self.metrics_log.append(metrics)
            if (step + 1) % self.save_every == 0:
                self.ckpt.save_async(step + 1, self.state)
        return self.metrics_log

    def _track_straggler(self, step: int, dt: float):
        # ignore the first (compile) step for the baseline
        if len(self.step_times) >= 3:
            med = median(self.step_times[1:])
            if dt > self.straggler_factor * med:
                self.straggler_count += 1
                if self.on_straggler:
                    self.on_straggler(step, dt)
        self.step_times.append(dt)

    def save_now(self, step: int):
        self.ckpt.save(step, self.state)
