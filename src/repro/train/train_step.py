"""train_step factory: loss scaling + microbatch accumulation + AdamW,
pjit-ready (shardings supplied by the launcher).

TrainState pytree:
    params     — compute-dtype weights (bf16 under HFP8)
    opt        — AdamW state (master + moments, f32 or narrow)
    lscale     — dynamic loss-scale state (present iff policy.loss_scaling)
    ef         — error feedback for the compressed DP gradient wire
                 (present iff dp_compress; DESIGN.md §13)
    rng        — PRNG key (stochastic rounding, future dropout)

The step:
  1. (scan over microbatches) f32 gradient accumulation — the "expanding
     accumulation" rule applied at the gradient level;
  2. unscale + finite check -> maybe-skip (fault-tolerant numerics);
  3. global clip + AdamW with wide arithmetic.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core.policy import get_policy
from ..core.scaling import loss_scale_init, check_and_update_scale
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.grad_compress import compressed_psum_mean, error_feedback_init

__all__ = ["make_train_state", "make_train_step"]


def make_train_state(model, key, opt_cfg: AdamWConfig, *,
                     dp_compress: bool = False):
    params = model.init(key)
    policy = get_policy(model.cfg.policy_name)
    state = {
        "params": params,
        "opt": adamw_init(params, opt_cfg),
        "rng": jax.random.key_data(jax.random.key(0)),
    }
    if policy.loss_scaling:
        state["lscale"] = loss_scale_init()
    if dp_compress:
        # per-leaf error feedback for the compressed DP gradient wire
        # (DESIGN.md §13) — shaped like the grads, carried like opt state
        state["ef"] = error_feedback_init(params)
    return state


def make_train_step(model, opt_cfg: AdamWConfig, *, microbatches: int = 1,
                    rules=None, impl: str = "auto", remat: bool = True,
                    dp_compress: bool = False):
    policy = get_policy(model.cfg.policy_name)
    if dp_compress and (rules is None or rules.mesh is None
                        or not rules.batch_axes):
        raise ValueError("dp_compress needs mesh rules with a batch axis")
    # the wire compresses the *slowest* reduction hop: the pod axis when
    # the mesh has one (cross-pod DCN), else the data axis
    dp_axis = None
    if dp_compress:
        names = rules.mesh.axis_names
        dp_axis = "pod" if "pod" in names else rules.batch_axes[0]

    def train_step(state, tokens, aux=None):
        params = state["params"]
        scale = (state["lscale"]["scale"] if policy.loss_scaling
                 else jnp.float32(1.0))

        def loss_fn(p, toks, a):
            return model.loss(p, toks, aux=a, rules=rules, impl=impl,
                              remat=remat) * scale

        if microbatches > 1:
            gb = tokens.shape[0]
            mb = gb // microbatches
            toks = tokens.reshape(microbatches, mb, *tokens.shape[1:])
            auxs = (jax.tree.map(
                lambda x: x.reshape(microbatches, mb, *x.shape[1:]), aux)
                if aux is not None else None)

            def acc_body(carry, inp):
                gacc, lacc = carry
                t = inp[0]
                a = inp[1] if auxs is not None else None
                l, g = jax.value_and_grad(loss_fn)(params, t, a)
                gacc = jax.tree.map(
                    lambda ga, gi: ga + gi.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            inp = (toks, auxs) if auxs is not None else (toks,)
            (grads, loss), _ = jax.lax.scan(acc_body, (g0, jnp.float32(0)),
                                            inp)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens, aux)

        metrics = {}
        if policy.loss_scaling:
            grads, new_ls, skip = check_and_update_scale(
                state["lscale"], grads)
            metrics["loss_scale"] = new_ls["scale"]
            metrics["skipped"] = skip.astype(jnp.int32)
        else:
            new_ls, skip = None, None
            # still guard against stray non-finite grads at scale
            finite = jnp.array(True)
            for g in jax.tree.leaves(grads):
                finite &= jnp.all(jnp.isfinite(g.astype(jnp.float32)))
            skip = ~finite
            metrics["skipped"] = skip.astype(jnp.int32)

        new_ef = None
        if dp_compress:
            # compressed DP mean over the slow axis (post-unscale so the
            # wire sees true-magnitude grads).  Wire poison — NaN-scale
            # groups from a non-finite leaf — must reach the skip, so
            # re-check finiteness after the reduction and OR it in; the
            # EF reset inside the wire keeps next step's state clean.
            grads, new_ef = compressed_psum_mean(
                grads, state["ef"], rules.mesh, dp_axis,
                mx=policy.mx_dp_grad or None)
            finite = jnp.array(True)
            for g in jax.tree.leaves(grads):
                finite &= jnp.all(jnp.isfinite(g))
            skip = skip | ~finite
            metrics["skipped"] = skip.astype(jnp.int32)

        rng = jax.random.wrap_key_data(state["rng"])
        rng, sub = jax.random.split(rng)
        newp, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], params, opt_cfg, skip=skip,
            rng=sub if opt_cfg.stochastic_round else None)
        metrics.update(opt_metrics)
        metrics["loss"] = loss / scale

        new_state = {"params": newp, "opt": new_opt,
                     "rng": jax.random.key_data(rng)}
        if new_ls is not None:
            new_state["lscale"] = new_ls
        if new_ef is not None:
            new_state["ef"] = new_ef
        return new_state, metrics

    return train_step
