"""Cross-version jax API shims.

The codebase targets the modern jax API surface; installed images can lag
by several minor versions.  Every spot that touches a recently-renamed
symbol goes through here so the rest of the tree stays on one spelling.

Covered:
  * ``shard_map``          — ``jax.shard_map`` vs ``jax.experimental.shard_map``
  * ``make_mesh``          — ``axis_types=`` kwarg only exists on newer jax
  * ``set_mesh``           — ``jax.set_mesh`` vs the ``Mesh`` context manager
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.6 re-exports shard_map at top level
    from jax import shard_map as _shard_map_raw
except ImportError:  # older jax: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map_raw

__all__ = ["shard_map", "make_mesh", "set_mesh"]

_SHARD_MAP_PARAMS = frozenset(
    inspect.signature(_shard_map_raw).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=None):
    """``shard_map`` accepting the modern kwargs on any jax.

    * ``check_vma``   — called ``check_rep`` before jax 0.6;
    * ``axis_names``  — the manual axes; older jax expresses the same set
      as its complement, ``auto`` (mesh axes left under GSPMD).
    """
    kwargs = {}
    if "axis_names" in _SHARD_MAP_PARAMS:  # modern spelling
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
    else:
        if check_vma is not None:
            kwargs["check_rep"] = check_vma
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
            if auto:
                kwargs["auto"] = auto
    return _shard_map_raw(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    New jax spells this ``jax.set_mesh``; older releases use the ``Mesh``
    object itself as the context manager.  **Always use the return value
    with ``with``** — on older jax nothing happens until the context is
    entered, so a bare call is a silent no-op there.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
