"""ModelConfig — one dataclass covers the whole assigned-architecture pool.

Each ``src/repro/configs/<arch>.py`` instantiates this with the exact
published numbers; ``reduced()`` derives the CPU smoke-test variant.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "ShapeCfg", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | xlstm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp: str = "gated_silu"     # gated_silu | gelu
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    pos_embed: str = "rope"     # rope | learned
    tie_embeddings: bool = False
    causal: bool = True
    # ---- MoE ----
    n_experts: int = 0
    top_k: int = 0
    moe_dense_ff: int = 0       # arctic: parallel dense residual FFN width
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # ---- SSM / hybrid ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0         # zamba2: shared attention block period
    slstm_every: int = 0        # xlstm: sLSTM block period (rest mLSTM)
    # ---- enc-dec (whisper) ----
    n_enc_layers: int = 0
    enc_seq: int = 1500         # precomputed audio-frame embeddings (stub)
    # ---- VLM ----
    n_patches: int = 0          # precomputed patch embeddings (stub)
    frontend_dim: int = 0       # raw frontend embedding width
    # ---- numerics / paper technique ----
    policy_name: str = "hfp8"
    quantize_head: bool = False # keep first/last layer un-quantized (HFP8)
    # ---- attention impl ----
    attn_q_chunk: int = 1024    # q-chunked exact attention (memory-safe)

    # ------------------------------------------------------------------
    @property
    def head_dim_eff(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim_eff

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim_eff

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_headdim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM/linear-attn families)"""
        return self.family in ("xlstm", "hybrid")

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_dense_ff=64 if self.moe_dense_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=16,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            frontend_dim=32 if self.frontend_dim else 0,
            attn_q_chunk=8,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}
