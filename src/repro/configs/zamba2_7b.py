"""zamba2-7b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242;
unverified]. 81 Mamba2 layers; one *shared-weight* attention+MLP block
applied after every 6 Mamba2 layers (per-application KV caches)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,             # shared block MLP width
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    attn_every=6,
)
