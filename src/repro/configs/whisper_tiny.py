"""whisper-tiny [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified].

The conv frontend is a STUB per assignment: input_specs() provides
precomputed audio-frame embeddings [B, 1500, d_model].
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,             # decoder layers
    n_enc_layers=4,         # encoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp="gelu",
    norm="layernorm",
    pos_embed="learned",
    enc_seq=1500,
)
