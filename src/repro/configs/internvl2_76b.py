"""internvl2-76b [vlm] — InternViT + InternLM2 backbone
[arXiv:2404.16821; unverified].

The InternViT frontend is a STUB per assignment: input_specs() provides
precomputed patch embeddings [B, n_patches, frontend_dim] which are
projected into the LM width.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=1_000_000.0,
    n_patches=256,
    frontend_dim=3200,      # InternViT-6B width
)
