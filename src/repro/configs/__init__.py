"""Assigned architecture configs (exact published numbers) + registry."""
from .base import ModelConfig, ShapeCfg, SHAPES
from .deepseek_7b import CONFIG as deepseek_7b
from .llama3_2_3b import CONFIG as llama3_2_3b
from .qwen2_5_3b import CONFIG as qwen2_5_3b
from .stablelm_1_6b import CONFIG as stablelm_1_6b
from .xlstm_125m import CONFIG as xlstm_125m
from .arctic_480b import CONFIG as arctic_480b
from .granite_moe_3b import CONFIG as granite_moe_3b
from .whisper_tiny import CONFIG as whisper_tiny
from .zamba2_7b import CONFIG as zamba2_7b
from .internvl2_76b import CONFIG as internvl2_76b

ARCHS = {c.name: c for c in (
    deepseek_7b, llama3_2_3b, qwen2_5_3b, stablelm_1_6b, xlstm_125m,
    arctic_480b, granite_moe_3b, whisper_tiny, zamba2_7b, internvl2_76b)}


def get_arch(name: str) -> ModelConfig:
    return ARCHS[name.replace("_", "-")] if name.replace(
        "_", "-") in ARCHS else ARCHS[name]
