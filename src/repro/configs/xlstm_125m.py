"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0: xLSTM blocks carry their own up/down projections, no separate FFN.
Every 4th block is sLSTM (recurrent gate feedback); the rest are mLSTM.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=4,
)
