"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

The paper technique is what makes this arch *fit* a 256-chip v5e pod:
fp8 parameter storage + fp16 master + bf16 moments (DESIGN.md §7).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,              # expert FFN width
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    moe_dense_ff=4864,      # parallel dense residual FFN
    rope_theta=10_000.0,
)
