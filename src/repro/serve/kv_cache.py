"""Packed paged KV cache for serving (DESIGN.md §12).

The serving cache is a fixed pool of page slots shared by every
sequence in the batch, instead of one contiguous ``[B, max_len, ...]``
strip per sequence:

* **pool** — per layer, ``[P, page_size, KV, ...]`` arrays where
  ``P = 1 + batch · max_pages``; page 0 is a reserved *trash page* that
  absorbs out-of-range writes (a position past a sequence's page table
  routes there instead of clobbering live data).
* **page table** ``pt [B, max_pages]`` int32 — row ``b`` lists the pool
  pages backing sequence ``b`` in order; unallocated entries are 0
  (the trash page), whose garbage contents the decode kernel excludes
  structurally via ``lens``.
* **lens [B]`` int32 — live prefix length per sequence (cache slots
  ``0..lens-1`` are history; an attend of S new rows writes
  ``lens..lens+S-1``).

Under an MX serving policy (``policy.mx_kv_cache_name``) with a
group-aligned head dim, pool pages hold *packed* codec payloads +
E8M0 scale codes — the exact bytes ``ops.mx_quantize_kv`` emits, at
0.53–1.03 B/elem instead of 2 (bf16) — and attention runs the packed
decode kernel, dequantizing groups in-register.  Otherwise pages hold
carrier-precision k/v (the bf16 fallback: same paging, full bytes).

The page table itself is model state but *policy-free*: schedulers
(``serve.scheduler``) rewrite ``pt``/``lens`` host-side to admit,
grow, and retire sequences mid-flight; the simple ``generate`` path
uses the static identity table this module preallocates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.formats import get_mx_format
from ..core.policy import get_policy
from ..kernels import ops

__all__ = ["paged_kv_applicable", "max_pages", "init_paged_kv",
           "paged_attend", "paged_kv_bytes_per_seq"]


def paged_kv_applicable(cfg, policy) -> bool:
    """Packed pages? Requires an MX cache format and a head dim that
    tiles into whole scale groups; anything else serves carrier pages."""
    policy = get_policy(policy)
    name = policy.mx_kv_cache_name
    if not name:
        return False
    return cfg.head_dim_eff % get_mx_format(name).group == 0


def max_pages(max_len: int, page_size: int) -> int:
    return -(-max_len // page_size)


def init_paged_kv(cfg, policy, batch: int, max_len: int, *,
                  page_size: int = 16, dtype=jnp.bfloat16):
    """One layer's page pool + the shared (pt, lens) tables.

    Returns ``(kv, pt, lens)``: ``kv`` is the per-layer leaf dict
    (packed: kp/ks/vp/vs; carrier: k/v), ``pt [B, MP]`` the identity
    page table (slot ``j`` of sequence ``b`` -> page ``1 + b·MP + j``),
    ``lens [B]`` zeros.  Pool size ``P = 1 + batch · MP`` — page 0 is
    the trash page."""
    policy = get_policy(policy)
    mp = max_pages(max_len, page_size)
    p_pool = 1 + batch * mp
    kv_h, hd = cfg.n_kv_heads, cfg.head_dim_eff
    if paged_kv_applicable(cfg, policy):
        mx = get_mx_format(policy.mx_kv_cache_name)
        from ..kernels.codec import get_codec
        pw = get_codec(mx).packed_cols(hd)
        kv = {
            "kp": jnp.zeros((p_pool, page_size, kv_h, pw), jnp.uint8),
            "ks": jnp.zeros((p_pool, page_size, kv_h, hd // mx.group),
                            jnp.uint8),
            "vp": jnp.zeros((p_pool, page_size, kv_h, pw), jnp.uint8),
            "vs": jnp.zeros((p_pool, page_size, kv_h, hd // mx.group),
                            jnp.uint8),
        }
    else:
        kv = {
            "k": jnp.zeros((p_pool, page_size, kv_h, hd), dtype),
            "v": jnp.zeros((p_pool, page_size, kv_h, hd), dtype),
        }
    pt = 1 + jnp.arange(batch * mp, dtype=jnp.int32).reshape(batch, mp)
    lens = jnp.zeros((batch,), jnp.int32)
    return kv, pt, lens


def paged_kv_bytes_per_seq(cfg, policy, max_len: int, *,
                           page_size: int = 16,
                           carrier_bytes: int = 2) -> int:
    """HBM cache bytes one sequence's pages pin, per layer-stack total
    — the quantity BENCH_serve gates."""
    policy = get_policy(policy)
    mp = max_pages(max_len, page_size)
    elems = page_size * cfg.n_kv_heads * cfg.head_dim_eff
    if paged_kv_applicable(cfg, policy):
        mx = get_mx_format(policy.mx_kv_cache_name)
        per_page = int(2 * elems * mx.packed_bytes_per_element)
    else:
        per_page = 2 * elems * carrier_bytes
    return cfg.n_layers * mp * per_page


def _slot_index(pt, lens, s, page_size):
    """Pool coordinates for the S new rows: (pidx [B,S], off [B,S]).

    Positions past the page table route to the trash page 0."""
    mp = pt.shape[1]
    pos = lens[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    pcol = pos // page_size
    inb = pcol < mp
    pidx = jnp.take_along_axis(pt, jnp.minimum(pcol, mp - 1), axis=1)
    pidx = jnp.where(inb, pidx, 0)
    return pidx, pos % page_size


def _gather(leaf, pt):
    """[P, page, KV, W] pool + [B, MP] table -> [B, MP·page, KV, W]."""
    b, mp = pt.shape
    pages = leaf[pt]                       # [B, MP, page, KV, W]
    return pages.reshape(b, mp * pages.shape[2], *leaf.shape[2:])


def _heads_to_rows(x, n_heads):
    """[B, T, KV, W] -> [B·H, T, W] with GQA repeat along heads."""
    b, t, kv_h, w = x.shape
    x = jnp.repeat(x, n_heads // kv_h, axis=2)
    return x.transpose(0, 2, 1, 3).reshape(b * n_heads, t, w)


def paged_attend(q, k_new, v_new, kv, pt, lens, *, cfg, policy,
                 impl: str = "auto"):
    """Append S rows to the paged cache and attend against it.

    ``q [B,S,H,hd]``, ``k_new/v_new [B,S,KV,hd]`` (RoPE already
    applied with per-sequence absolute positions); returns
    ``(out [B,S,H,hd], new_kv)`` — the functionally-updated pool
    leaves.  Packed pools quantize the new rows once on the way in
    (``ops.mx_quantize_kv``) and the decode kernel streams payloads;
    carrier pools store ``k_new`` at pool dtype.
    """
    policy = get_policy(policy)
    b, s, h, hd = q.shape
    page_size = next(iter(kv.values())).shape[1]
    pidx, off = _slot_index(pt, lens, s, page_size)
    lens_r = jnp.repeat(lens, h)

    if "kp" in kv:
        name = policy.mx_kv_cache_name
        kp, ks8 = ops.mx_quantize_kv(k_new, name, impl=impl)
        vp, vs8 = ops.mx_quantize_kv(v_new, name, impl=impl)
        new_kv = {"kp": kv["kp"].at[pidx, off].set(kp),
                  "ks": kv["ks"].at[pidx, off].set(ks8),
                  "vp": kv["vp"].at[pidx, off].set(vp),
                  "vs": kv["vs"].at[pidx, off].set(vs8)}
        args = [_heads_to_rows(_gather(new_kv[n], pt), h)
                for n in ("kp", "ks", "vp", "vs")]
        out = ops.mx_decode_attention_packed(
            q.transpose(0, 2, 1, 3).reshape(b * h, s, hd), *args, lens_r,
            mx_k=name, impl=impl)
    else:
        new_kv = {"k": kv["k"].at[pidx, off].set(k_new.astype(
                      kv["k"].dtype)),
                  "v": kv["v"].at[pidx, off].set(v_new.astype(
                      kv["v"].dtype))}
        kg = _heads_to_rows(_gather(new_kv["k"], pt), h)
        vg = _heads_to_rows(_gather(new_kv["v"], pt), h)
        out = ops.decode_attention(
            q.transpose(0, 2, 1, 3).reshape(b * h, s, hd), kg, vg, lens_r,
            impl=impl)
    out = out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return out.astype(q.dtype), new_kv
