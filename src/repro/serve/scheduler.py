"""Continuous batching over the paged KV cache (DESIGN.md §12).

The batcher owns a fixed pool of decode slots (``max_batch``) and a
page allocator over the shared pool; requests flow through a slot
state machine::

    pending --admit--> prefill --first token--> decoding --stop--> free
                 (pages alloc'd)        (page per boundary)  (pages freed)

* **admit** — a free slot takes the oldest pending request: its pages
  are allocated, the prompt prefills in ONE block ``decode_step`` on a
  single-slot *view* of the shared cache (the pool is functionally
  updated, so the slot's pages land in the common arrays), and the
  first token is sampled from the prefill logits.
* **decode** — all active slots advance in lockstep: one batched
  ``decode_step`` over ``[max_batch]`` tokens.  Idle slots ride along
  pinned at ``lens = 0`` with an all-trash page table; their logits
  are garbage and discarded.  A slot crossing a page boundary gets its
  next page allocated just before the step.
* **retire** — finished sequences free their pages back to the
  allocator and zero their table row.  Freed pages keep their stale
  payloads (possibly NaN-poisoned scale codes); the decode kernel's
  structural garbage masking is what makes skipping the scrub safe.

``pt``/``lens`` live host-side (numpy) as the scheduler's ground
truth and are pushed into the device cache each step — the cache's
own ``lens + s`` advance is ignored, which is also what keeps idle
slots from drifting.

Greedy decoding reproduces ``serve.decode.generate`` token for token:
same kernels, same cache math — only the page *numbering* differs,
and the gather re-assembles identical sequences either way.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kv_cache import max_pages

__all__ = ["ServeRequest", "PageAllocator", "ContinuousBatcher"]


@dataclasses.dataclass
class ServeRequest:
    uid: Any
    prompt: np.ndarray            # [P] int32 token ids
    max_new_tokens: int


class PageAllocator:
    """Free-list over pool pages 1..P-1 (page 0 is the trash page)."""

    def __init__(self, n_pages: int):
        self._free = list(range(n_pages - 1, 0, -1))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise RuntimeError(f"page pool exhausted: want {n}, "
                               f"have {len(self._free)}")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages) -> None:
        for p in pages:
            assert p > 0, "page 0 is reserved"
            self._free.append(int(p))


@dataclasses.dataclass
class _Slot:
    req: ServeRequest
    remaining: int
    tok: int                      # last sampled token (next step's input)
    out: list


class ContinuousBatcher:
    """Mid-flight admission + lockstep paged decode for one model.

    ``model`` must support block decode and a paged cache
    (``init_cache(..., paged=True)``) — the dense/MoE families.
    """

    def __init__(self, model, params, *, max_batch: int, max_len: int,
                 page_size: int = 16, temperature: float = 0.0,
                 key=None, rules=None, impl: str = "auto",
                 eos_id: Optional[int] = None):
        if temperature > 0.0 and key is None:
            raise ValueError("temperature>0 requires key=")
        assert getattr(model, "block_decode", False), model.cfg.family
        self.model, self.params = model, params
        self.max_batch, self.max_len = max_batch, max_len
        self.page_size = page_size
        self.temperature, self.key, self.eos_id = temperature, key, eos_id
        self.mp = max_pages(max_len, page_size)
        self.cache = model.init_cache(max_batch, max_len, paged=True,
                                      page_size=page_size)
        self.alloc = PageAllocator(1 + max_batch * self.mp)
        # scheduler-owned tables (the init identity table is discarded)
        self.pt = np.zeros((max_batch, self.mp), np.int32)
        self.lens = np.zeros((max_batch,), np.int32)
        self.slots: list[Optional[_Slot]] = [None] * max_batch
        self.pending: deque[ServeRequest] = deque()
        self.done: dict[Any, np.ndarray] = {}
        self._step = jax.jit(functools.partial(model.decode_step,
                                               rules=rules, impl=impl))

    # ------------------------------------------------------------- state --

    def _push_tables(self):
        self.cache = {**self.cache, "pt": jnp.asarray(self.pt),
                      "lens": jnp.asarray(self.lens)}

    def _ensure(self, b: int, pos: int):
        """Back cache slot ``pos`` of sequence ``b`` with a real page."""
        j = pos // self.page_size
        assert j < self.mp, (pos, self.max_len)
        if self.pt[b, j] == 0:
            self.pt[b, j] = self.alloc.alloc(1)[0]

    def _sample(self, logits) -> np.ndarray:
        if self.temperature > 0.0:
            self.key, sub = jax.random.split(self.key)
            return np.asarray(jax.random.categorical(
                sub, jnp.asarray(logits, jnp.float32) / self.temperature,
                axis=-1))
        # matches generate's jnp.argmax tie-breaking (first max)
        return np.asarray(jnp.argmax(jnp.asarray(logits), axis=-1))

    # ------------------------------------------------------- transitions --

    def _admit(self):
        for b in range(self.max_batch):
            if self.slots[b] is not None or not self.pending:
                continue
            req = self.pending.popleft()
            prompt = np.asarray(req.prompt, np.int32)
            p = len(prompt)
            assert p + req.max_new_tokens <= self.max_len, req.uid
            self.lens[b] = 0
            for pos in range(p):
                self._ensure(b, pos)
            # single-slot view prefill: pool leaves are shared, so the
            # functional update lands the pages in the common arrays
            view = {"kv": self.cache["kv"],
                    "pt": jnp.asarray(self.pt[b:b + 1]),
                    "lens": jnp.zeros((1,), jnp.int32)}
            logits, view = self._step(self.params, jnp.asarray(prompt[None]),
                                      view)
            self.cache = {**self.cache, "kv": view["kv"]}
            self.lens[b] = p
            tok = int(self._sample(logits[:, -1])[0])
            slot = _Slot(req, req.max_new_tokens - 1, tok, [tok])
            if self._finished(slot):
                self._retire(b, slot)
            else:
                self.slots[b] = slot

    def _finished(self, slot: _Slot) -> bool:
        return slot.remaining <= 0 or (self.eos_id is not None
                                       and slot.tok == self.eos_id)

    def _retire(self, b: int, slot: _Slot):
        self.done[slot.req.uid] = np.asarray(slot.out, np.int32)
        self.alloc.free(self.pt[b][self.pt[b] != 0])
        self.pt[b] = 0
        self.lens[b] = 0
        self.slots[b] = None

    # -------------------------------------------------------------- step --

    def step(self):
        """One scheduler tick: admit, lockstep-decode, retire."""
        self._admit()
        active = [b for b in range(self.max_batch)
                  if self.slots[b] is not None]
        if not active:
            return
        toks = np.zeros((self.max_batch,), np.int32)
        for b in active:
            toks[b] = self.slots[b].tok
            self._ensure(b, int(self.lens[b]))
        self._push_tables()
        logits, new_cache = self._step(self.params, jnp.asarray(toks),
                                       self.cache)
        # keep the updated pool; device pt/lens are overwritten from the
        # host tables on the next push (idle slots stay pinned at 0)
        self.cache = {**self.cache, "kv": new_cache["kv"]}
        sampled = self._sample(logits)
        for b in active:
            self.lens[b] += 1
            slot = self.slots[b]
            slot.tok = int(sampled[b])
            slot.out.append(slot.tok)
            slot.remaining -= 1
            if self._finished(slot):
                self._retire(b, slot)

    def run(self, requests) -> dict:
        self.pending.extend(requests)
        while self.pending or any(s is not None for s in self.slots):
            self.step()
        return self.done
