"""Serving: batched prefill + single-token decode steps (pjit-ready).

``serve_step`` is what the ``decode_*``/``long_*`` dry-run cells lower:
one new token against a KV/state cache of ``seq_len``. Sampling is greedy
or temperature-categorical; generation loops on the host (one jitted step
per token) exactly like a production decode server.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["make_serve_fns", "generate"]


def make_serve_fns(model, *, rules=None, impl: str = "auto"):
    def prefill(params, tokens, cache, aux=None):
        """Teacher-forced prefill producing logits; for cache-filling
        prefill, decode_step is called per position (enc-dec archs fill
        cross-attn caches via model.prefill_cache)."""
        logits, _ = model.apply(params, tokens, aux=aux, rules=rules,
                                impl=impl)
        return logits

    def serve_step(params, tok, cache):
        """One new token [B] against the current cache -> (logits, cache)."""
        return model.decode_step(params, tok, cache, rules=rules, impl=impl)

    return prefill, serve_step


def generate(model, params, prompt, *, max_new_tokens: int, max_len: int,
             temperature: float = 0.0, key=None, rules=None,
             impl: str = "auto", aux=None):
    """Greedy/temperature decoding from a [B, S] prompt."""
    b, s = prompt.shape
    cache = model.init_cache(b, max_len)
    if model.cfg.family == "encdec" and aux is not None:
        cache = model.prefill_cache(params, aux["frames"], cache,
                                    rules=rules, impl=impl)
    step = jax.jit(functools.partial(model.decode_step, rules=rules,
                                     impl=impl))
    # feed the prompt token by token (cache fill)
    logits = None
    for i in range(s):
        logits, cache = step(params, prompt[:, i], cache)
    toks = []
    tok = None
    for i in range(max_new_tokens):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub,
                                         logits.astype(jnp.float32)
                                         / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        toks.append(tok)
        logits, cache = step(params, tok, cache)
    return jnp.stack(toks, axis=1)
