"""Serving: batched prefill + single-token decode steps (pjit-ready).

``serve_step`` is what the ``decode_*``/``long_*`` dry-run cells lower:
one new token against a KV/state cache of ``seq_len``. Sampling is greedy
or temperature-categorical; generation loops on the host (one jitted step
per token) exactly like a production decode server.

``generate`` prefills the prompt in ONE ``decode_step`` call when the
model supports block decode (attention families — [B, S] tokens in,
[B, S, V] logits out) and falls back to per-token cache fill for the
recurrent families.  Under an MX policy the cache defaults to the
packed paged pool (``serve.kv_cache``); ``paged=False`` forces the
contiguous carrier strip.  For mid-flight admission and page-level
scheduling, see ``serve.scheduler.ContinuousBatcher``.
"""
from __future__ import annotations

import functools
import inspect
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["make_serve_fns", "generate"]


def make_serve_fns(model, *, rules=None, impl: str = "auto"):
    def prefill(params, tokens, cache, aux=None):
        """Teacher-forced prefill producing logits; for cache-filling
        prefill, decode_step is called per position (enc-dec archs fill
        cross-attn caches via model.prefill_cache)."""
        logits, _ = model.apply(params, tokens, aux=aux, rules=rules,
                                impl=impl)
        return logits

    def serve_step(params, tok, cache):
        """One new token [B] against the current cache -> (logits, cache)."""
        return model.decode_step(params, tok, cache, rules=rules, impl=impl)

    return prefill, serve_step


def _init_cache(model, batch, max_len, paged, page_size):
    kw = {}
    if "paged" in inspect.signature(model.init_cache).parameters:
        kw = {"paged": paged, "page_size": page_size}
    return model.init_cache(batch, max_len, **kw)


def generate(model, params, prompt, *, max_new_tokens: int, max_len: int,
             temperature: float = 0.0, key=None, rules=None,
             impl: str = "auto", aux=None, paged=None, page_size: int = 16):
    """Greedy/temperature decoding from a [B, S] prompt."""
    if temperature > 0.0 and key is None:
        raise ValueError("temperature>0 requires key=")
    b, s = prompt.shape
    cache = _init_cache(model, b, max_len, paged, page_size)
    if model.cfg.family == "encdec" and aux is not None:
        cache = model.prefill_cache(params, aux["frames"], cache,
                                    rules=rules, impl=impl)
    step = jax.jit(functools.partial(model.decode_step, rules=rules,
                                     impl=impl))
    if getattr(model, "block_decode", False):
        # block prefill: the whole prompt in one step (paged caches
        # scatter S rows at once; carrier caches fill slots 0..S-1)
        logits, cache = step(params, prompt, cache)
        logits = logits[:, -1]
    else:
        # recurrent families: strict per-token cache fill
        logits = None
        for i in range(s):
            logits, cache = step(params, prompt[:, i], cache)
    toks = []
    tok = None
    for i in range(max_new_tokens):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub,
                                         logits.astype(jnp.float32)
                                         / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        toks.append(tok)
        logits, cache = step(params, tok, cache)
    return jnp.stack(toks, axis=1)
