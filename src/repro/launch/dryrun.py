import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import: jax locks the device
# count at first init, and the dry-run needs 512 placeholder host devices
# to build the production meshes. (Only this entry point does this — tests
# and benches see the real single CPU device.)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions cleanly at 256/512
    chips — sharding mismatches, unsupported collectives and compile-time
    OOMs all fail here);
  * the memory footprint fits (memory_analysis, bytes per device);
  * the roofline inputs (cost_analysis FLOPs/bytes + HLO collective bytes)
    — consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all            # every applicable cell
  python -m repro.launch.dryrun --all --jobs 4   # subprocess per cell
"""
import argparse
import json
import re
import subprocess
import sys
import time

import jax

from ..compat import set_mesh

HLO_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "f8e4m3fn": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
    "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVE_RE = re.compile(
    r"=\s+(\(?[a-z0-9\[\],{}: ]+?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"([a-z]\d?[a-z0-9]*)\[([\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(txt):
        if dt not in HLO_DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * HLO_DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective type (result-shape convention;
    all-reduce counted x2 for its reduce-scatter + all-gather phases)."""
    out = {k: 0 for k in ("all-reduce", "all-gather", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        b = _shape_bytes(m.group(1))
        out[kind] += b * (2 if kind == "all-reduce" else 1)
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def model_flops_estimate(cfg, shape, params_shapes) -> dict:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params
    excluding the embedding table lookup; + causal-attention term."""
    import numpy as np

    def leaves_with_paths(tree):
        return jax.tree_util.tree_flatten_with_path(tree)[0]

    total = active = embed = 0
    for path, leaf in leaves_with_paths(params_shapes):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        n = int(np.prod(leaf.shape))
        total += n
        if "embed" in pstr and "lm_head" not in pstr:
            embed += n
        if "experts" in pstr and cfg.n_experts:
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    n_active = active - embed
    if cfg.tie_embeddings:
        # tied head: the embedding matrix IS the logits GEMM weight
        n_active += cfg.vocab_size * cfg.d_model
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                 else 1)
    mult = 6 if shape.kind == "train" else 2
    flops = mult * n_active * toks
    # causal attention: 2 matmuls * 2 flops * (S^2/2) * d_attn * H * L * B
    if cfg.family not in ("xlstm",):
        s_ctx = shape.seq_len
        s_q = shape.seq_len if shape.kind != "decode" else 1
        att = (2 * 2 * 0.5 * s_q * s_ctx * cfg.head_dim_eff * cfg.n_heads
               * cfg.n_layers * shape.global_batch)
        if cfg.family == "hybrid":
            att *= (cfg.n_layers // max(cfg.attn_every, 1)) / cfg.n_layers
        flops += att * (3 if shape.kind == "train" else 1)
    return {"params_total": int(total), "params_active_nonembed":
            int(n_active), "model_flops_global": float(flops)}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             *, seq_shard=None, microbatches=1, opt_overrides=None) -> dict:
    from ..configs import get_arch
    from ..configs.base import SHAPES
    from ..launch.mesh import make_production_mesh
    from ..launch.specs import build_cell, cell_is_applicable, shardings_for
    from ..optim.adamw import AdamWConfig

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    ok, why = cell_is_applicable(cfg, shape)
    rec = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
           "kind": shape.kind}
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(rec, out_dir)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    if shape.kind == "train" and microbatches == 1:
        # optimized default (§Perf D7): 4-way gradient accumulation keeps
        # activation temp inside HBM at identical wire bytes
        microbatches = 4
    if opt_overrides is None and cfg.name == "arctic-480b":
        # 480B params cannot carry f32 optimizer state at 256-512 chips
        # (DESIGN.md §7): fp16 master + bf16 moments, f32 update arithmetic
        import jax.numpy as _jnp
        opt_overrides = {"master_dtype": _jnp.float16,
                         "moment_dtype": _jnp.bfloat16}
    opt_cfg = AdamWConfig(**(opt_overrides or {}))
    fn, args, in_specs, donate, model, rules = build_cell(
        cfg, shape, mesh, opt_cfg=opt_cfg, seq_shard=seq_shard,
        microbatches=microbatches)
    in_shardings = shardings_for(in_specs, mesh)
    with set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=in_shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    print(ma)                           # proves it fits (bytes per device)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax wraps the dict in a list
        ca = ca[0] if ca else {}
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()

    # trip-count-weighted analysis: XLA's cost_analysis counts scan bodies
    # once; hlo_analysis weights every computation by its execution count.
    from .hlo_analysis import analyze
    h = analyze(hlo)

    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    rec.update(
        status="ok",
        n_devices=mesh.devices.size,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        flops_per_device=h["flops"],
        bytes_per_device=h["bytes"],
        collectives={"bytes": h["coll_bytes"],
                     "counts": h["coll_counts"],
                     "total_bytes": h["coll_total"]},
        raw_scan_once={"flops": float(ca.get("flops", 0.0)),
                       "bytes": float(ca.get("bytes accessed", 0.0))},
        memory=dict(
            argument_bytes=ma.argument_size_in_bytes,
            output_bytes=ma.output_size_in_bytes,
            temp_bytes=ma.temp_size_in_bytes,
            alias_bytes=ma.alias_size_in_bytes,
            code_bytes=ma.generated_code_size_in_bytes,
        ) if ma is not None else None,
        hlo_chars=len(hlo),
        **model_flops_estimate(cfg, shape, params_shapes),
    )
    _write(rec, out_dir)
    return rec


def _write(rec: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] {name}: {rec['status']}"
          + (f" ({rec.get('compile_s', '?')}s compile)"
             if rec["status"] == "ok" else f" — {rec.get('reason','')}"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    if not args.all:
        run_cell(args.arch, args.shape, args.multi_pod, args.out,
                 microbatches=args.microbatches)
        return

    from ..configs import ARCHS
    from ..configs.base import SHAPES
    cells = [(a, s, mp) for a in sorted(ARCHS) for s in SHAPES
             for mp in (False, True)]
    procs = []
    for a, s, mp in cells:
        done = os.path.join(
            args.out, f"{a}_{s}_{'pod2x16x16' if mp else 'pod16x16'}.json")
        if os.path.exists(done):
            print(f"[dryrun] skip existing {done}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
               "--shape", s, "--out", args.out]
        if mp:
            cmd.append("--multi-pod")
        if args.jobs == 1:
            subprocess.run(cmd, check=False)
        else:
            procs.append(subprocess.Popen(cmd))
            while len([p for p in procs if p.poll() is None]) >= args.jobs:
                time.sleep(2)
    for p in procs:
        p.wait()


if __name__ == "__main__":
    main()
