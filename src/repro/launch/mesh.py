"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state — required because the dry-run pins
``xla_force_host_platform_device_count`` before first jax init.

Topology: a TPU v5e pod is a 16x16 chip grid; ``data`` carries DP+ZeRO,
``model`` carries TP/EP/SP. The multi-pod mesh adds an outer ``pod`` axis
(DCN/ICI-slow hop) used for hierarchical data parallelism: ZeRO shards
stay *within* a pod, gradients cross pods once per step (optionally
FP8-compressed, optim/grad_compress.py).
"""
from __future__ import annotations

import jax

from ..compat import make_mesh

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires enough local devices)."""
    return make_mesh(shape, axes)
