"""Production serving launcher: batched decode loop with cache reuse.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --reduced --batch 4 --new-tokens 8
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..models import build_model
from ..serve.decode import make_serve_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    _, serve_step = make_serve_fns(model)
    step = jax.jit(serve_step)

    rng = np.random.default_rng(0)
    cache = model.init_cache(args.batch, args.max_len)
    if cfg.family == "encdec":
        frames = jnp.asarray(rng.normal(0, 1, (args.batch, cfg.enc_seq,
                                                cfg.d_model)), jnp.bfloat16)
        cache = model.prefill_cache(params, frames, cache)
    logits = None
    for i in range(args.prompt_len):
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch,)))
        logits, cache = step(params, tok, cache)
    t0 = time.perf_counter()
    for _ in range(args.new_tokens):
        tok = jnp.argmax(logits, axis=-1)
        logits, cache = step(params, tok, cache)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    print(f"[launch.serve] {cfg.name}: {args.batch}x{args.new_tokens} tokens "
          f"in {dt*1e3:.0f} ms ({args.batch*args.new_tokens/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
