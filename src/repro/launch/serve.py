"""Production serving launcher: block prefill + batched decode over the
paged KV cache (DESIGN.md §12).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --reduced --batch 4 --new-tokens 8 --policy mxfp8

Under an MX ``--policy`` (and a group-aligned head dim) the cache pages
hold packed codec payloads + E8M0 scales and decode runs the packed
kernel; otherwise carrier pages (or, for the recurrent families, their
native state caches).  The cache footprint line shows what the packed
pool pins in HBM per sequence vs bf16.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..core.policy import POLICIES
from ..models import build_model
from ..serve.decode import generate
from .hlo_analysis import format_serve_cache_footprint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default=None, choices=sorted(POLICIES),
                    help="override the arch's training policy for serving")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.policy:
        cfg = dataclasses.replace(cfg, policy_name=args.policy)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    if getattr(model, "block_decode", False):
        print(format_serve_cache_footprint(cfg, cfg.policy_name,
                                           args.max_len,
                                           page_size=args.page_size))

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (args.batch, args.prompt_len)))
    aux = None
    if cfg.family == "encdec":
        aux = {"frames": jnp.asarray(
            rng.normal(0, 1, (args.batch, cfg.enc_seq, cfg.d_model)),
            jnp.bfloat16)}
    t0 = time.perf_counter()
    out = generate(model, params, prompt, max_new_tokens=args.new_tokens,
                   max_len=args.max_len, aux=aux, page_size=args.page_size)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"[launch.serve] {cfg.name} policy={cfg.policy_name}: "
          f"{args.batch}x{args.new_tokens} tokens in {dt*1e3:.0f} ms "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
