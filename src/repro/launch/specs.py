"""input_specs / state specs / sharding trees for the dry-run.

Everything is ShapeDtypeStruct — weak-type-correct, shardable, zero
allocation. The full-size configs are only ever *lowered*, never run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeCfg, SHAPES
from ..core.policy import get_policy
from ..models import build_model
from ..parallel.sharding import param_pspecs, make_rules
from ..optim.adamw import AdamWConfig, adamw_init

__all__ = ["input_specs", "cell_is_applicable", "build_cell", "shardings_for"]


def cell_is_applicable(cfg: ModelConfig, shape: ShapeCfg) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: O(L^2) at 512k infeasible; "
                       "run for SSM/hybrid only (DESIGN.md §5)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    policy = get_policy(cfg.policy_name)
    cd = policy.compute_dtype
    b, s = shape.global_batch, shape.seq_len
    specs = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.family == "encdec":
            specs["aux"] = {"frames": jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), cd)}
        elif cfg.family == "vlm":
            specs["aux"] = {"patches": jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.frontend_dim), cd)}
    else:  # decode: one new token against a seq_len cache
        specs["tok"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        model = build_model(cfg)
        specs["cache"] = jax.eval_shape(
            lambda: model.init_cache(b, s))
    return specs


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(n: int, mesh: Mesh, axes) -> bool:
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return n % size == 0 and n >= size


def cache_pspecs(cache_shapes, mesh: Mesh):
    """Name-rule sharding for decode caches (kv, ssm state, conv, slstm)."""
    ba = _batch_axes(mesh)

    def spec(path, leaf):
        name = str(getattr(path[-1], "key", getattr(path[-1], "idx", "")))
        nd = len(leaf.shape)
        sh = leaf.shape
        axes = [None] * nd
        if name in ("k", "v") and nd >= 4:
            # [..., B, T, KV, hd]
            if _div(sh[nd - 4], mesh, ba):
                axes[nd - 4] = ba
            if _div(sh[nd - 2], mesh, "model"):
                axes[nd - 2] = "model"
        elif name == "h" and nd >= 4:
            # [..., B, H, dk, dv]
            if _div(sh[nd - 4], mesh, ba):
                axes[nd - 4] = ba
            if _div(sh[nd - 3], mesh, "model"):
                axes[nd - 3] = "model"
        elif name == "conv" and nd >= 3:
            # [..., B, K, C]
            if _div(sh[nd - 3], mesh, ba):
                axes[nd - 3] = ba
            if _div(sh[nd - 1], mesh, "model"):
                axes[nd - 1] = "model"
        elif name in ("hid", "c", "n", "m") and nd >= 2:
            # [..., B, D]
            if _div(sh[nd - 2], mesh, ba):
                axes[nd - 2] = ba
            if _div(sh[nd - 1], mesh, "model"):
                axes[nd - 1] = "model"
        return P(*axes)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def shardings_for(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def _retag_batch(pspec_tree, mesh):
    """Replace 'data' batch tags with the mesh's (pod,data) tuple where
    appropriate — param FSDP stays within-pod by design (hierarchical
    ZeRO), so params keep plain 'data'."""
    return pspec_tree


# ---------------------------------------------------------------------------
# build one dry-run cell: returns (fn, example_args, in_shardings, donate)
# ---------------------------------------------------------------------------

def build_cell(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh, *,
               opt_cfg: AdamWConfig | None = None, seq_shard: bool | None = None,
               impl: str = "xla", microbatches: int = 1):
    """Assemble the jittable step + arg specs + shardings for a cell."""
    from ..train.train_step import make_train_step
    model = build_model(cfg)
    policy = get_policy(cfg.policy_name)
    if seq_shard is None:
        # sequence parallelism (and with it the narrow-wire TP-GEMM path)
        # applies wherever full sequences flow: training and prefill.
        # Recurrent families (xlstm/hybrid) scan over time — sharding the
        # time dim forces per-chunk resharding inside the scan (measured
        # 10x bytes regression), so they stay batch-sharded.
        seq_shard = (shape.kind in ("train", "prefill")
                     and cfg.family not in ("xlstm", "hybrid"))
    rules = make_rules(mesh, seq_shard=seq_shard)
    ba = _batch_axes(mesh)

    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_pspecs = param_pspecs(params_shapes, mesh)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        state_shapes = {
            "params": params_shapes,
            "opt": jax.eval_shape(lambda p: adamw_init(p, opt_cfg),
                                  params_shapes),
            "rng": jax.ShapeDtypeStruct((2,), jnp.uint32),
        }
        def pod_zero(spec_tree):
            """Optimizer-state sharding additionally splits the FSDP dim
            across pods (hierarchical ZeRO-1: params replicate per pod,
            optimizer state does not — §Perf A1)."""
            if "pod" not in mesh.axis_names:
                return spec_tree

            def retag(s):
                return P(*[("data", "pod") if a == "data" else a
                           for a in s])

            return jax.tree.map(retag, spec_tree,
                                is_leaf=lambda x: isinstance(x, P))

        state_pspecs = {
            "params": p_pspecs,
            "opt": {"step": P(), "master": pod_zero(p_pspecs),
                    "m": pod_zero(p_pspecs), "v": pod_zero(p_pspecs)},
            "rng": P(),
        }
        if policy.loss_scaling:
            state_shapes["lscale"] = {
                "scale": jax.ShapeDtypeStruct((), jnp.float32),
                "good_steps": jax.ShapeDtypeStruct((), jnp.int32)}
            state_pspecs["lscale"] = {"scale": P(), "good_steps": P()}

        step = make_train_step(model, opt_cfg, rules=rules, impl=impl,
                               microbatches=microbatches)
        tok_spec = P(ba, None)
        args = (state_shapes, specs["tokens"])
        in_specs = (state_pspecs, tok_spec)
        if "aux" in specs:
            args = args + (specs["aux"],)
            in_specs = in_specs + (jax.tree.map(
                lambda _: P(ba, None, None), specs["aux"]),)
            fn = lambda st, t, a: step(st, t, aux=a)
        else:
            fn = step
        donate = (0,)
        return fn, args, in_specs, donate, model, rules

    if shape.kind == "prefill":
        def fn(params, tokens, aux=None):
            logits, _ = model.apply(params, tokens, aux=aux, rules=rules,
                                    impl=impl)
            return logits
        args = (params_shapes, specs["tokens"])
        in_specs = (p_pspecs, P(ba, None))
        if "aux" in specs:
            args = args + (specs["aux"],)
            in_specs = in_specs + (jax.tree.map(
                lambda _: P(ba, None, None), specs["aux"]),)
        return fn, args, in_specs, (), model, rules

    # decode
    cache_shapes = specs["cache"]
    c_pspecs = cache_pspecs(cache_shapes, mesh)

    def fn(params, tok, cache):
        return model.decode_step(params, tok, cache, rules=rules, impl=impl)

    tok_b = specs["tok"].shape[0]
    tok_spec = P(ba) if _div(tok_b, mesh, ba) else P()
    args = (params_shapes, specs["tok"], cache_shapes)
    in_specs = (p_pspecs, tok_spec, c_pspecs)
    return fn, args, in_specs, (2,), model, rules
