"""Production training launcher.

On a real multi-host TPU fleet this binary runs once per host
(jax.distributed.initialize is called when JAX_COORDINATOR is set); on
this container it runs the same code path on whatever devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --reduced --steps 20 --batch 8 --seq 64 --ckpt /tmp/ck

Flags mirror the dry-run cells: the same (arch x shape) configs that
compile at 512 chips run here at reduced scale; the mesh adapts to the
device count (elastic).
"""
import argparse
import os

import jax
import numpy as np

from ..compat import make_mesh

from ..configs import get_arch
from ..data.pipeline import DataConfig, SyntheticTokens
from ..models import build_model
from ..optim.adamw import AdamWConfig
from ..parallel.sharding import make_rules, param_pspecs
from ..train.train_step import make_train_state, make_train_step
from ..train.trainer import Trainer


def auto_mesh():
    """Build the largest (data, model) mesh the devices support."""
    n = len(jax.devices())
    if n == 1:
        return None
    model = 1
    for m in (16, 8, 4, 2):
        if n % m == 0:
            model = m
            break
    return make_mesh((n // model, model), ("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the arch")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_launch_train")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--policy", default=None)
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()  # multi-host fleet entry

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.policy:
        import dataclasses
        cfg = dataclasses.replace(cfg, policy_name=args.policy)
    model = build_model(cfg)
    mesh = auto_mesh()
    rules = make_rules(mesh) if mesh else None

    opt = AdamWConfig(total_steps=max(args.steps, 100))
    state = make_train_state(model, jax.random.key(0), opt)
    if mesh is not None:
        from jax.sharding import NamedSharding
        pspecs = param_pspecs(jax.eval_shape(lambda: state["params"]), mesh)
        shard = jax.tree.map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: hasattr(x, "_normalized_spec") or
            type(x).__name__ == "PartitionSpec")
        state["params"] = jax.tree.map(jax.device_put, state["params"], shard)
    step = make_train_step(model, opt, rules=rules,
                           microbatches=args.microbatches, impl="auto")
    data = SyntheticTokens(DataConfig(cfg.vocab_size, args.seq, args.batch))
    trainer = Trainer(model, step, state, data, ckpt_dir=args.ckpt,
                      save_every=args.save_every)
    if trainer.start_step:
        print(f"[launch.train] resumed at step {trainer.start_step}")
    log = trainer.run(args.steps)
    print(f"[launch.train] {cfg.name}: "
          f"loss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f}, "
          f"{len(log)} steps, stragglers={trainer.straggler_count}")


if __name__ == "__main__":
    main()
