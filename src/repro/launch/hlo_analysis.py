"""Trip-count-weighted HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — a
lax.scan over L layers under-reports FLOPs/bytes/collectives by ~L x.
This module parses the optimized HLO text instead and weights every
computation by its execution count (``known_trip_count`` backend config,
present for all scan-derived loops), giving per-device:

  * flops        — dot ops exactly (2 * result_elems * contracted size),
                   elementwise/reduce ops approximately (1 flop/elem),
                   fusion-internal ops included (XLA convention);
  * bytes        — operand + result bytes of every op outside fusion
                   bodies (fusions count their boundary, approximating
                   XLA's "bytes accessed");
  * collectives  — per-type wire bytes (all-reduce counted x2 for its
                   RS+AG phases), weighted by trip counts.

Validated against cost_analysis() on scan-free modules and for linearity
in scan depth (tests/test_dryrun.py).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import List, Optional

# Bytes per element.  Sub-byte dtypes are *fractional* (f4: two
# elements per byte, f6: four per three bytes, s4/u4 nibbles) so that
# byte accounting matches the packed storage layer (kernels/pack.py,
# DESIGN.md §9) instead of over-reporting packed payloads 2x.
DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e5m2": 1, "f8e4m3": 1, "f8e4m3fn": 1, "f8e3m4": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e8m0fnu": 1,
    "f6e2m3fn": 0.75, "f6e3m2fn": 0.75, "f4e2m1fn": 0.5,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "s2": 0.25, "u2": 0.25,
    "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(\(?.*?\)?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALL_ATTR_RE = re.compile(
    r"(?:body|condition|calls|to_apply|true_computation|false_computation)="
    r"%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_ELEMENTWISE_1 = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "tanh", "log", "rsqrt", "sqrt", "power",
    "compare", "select", "and", "or", "xor", "not", "sign", "floor",
    "ceil", "round-nearest-even", "cosine", "sine", "logistic",
    "exponential-minus-one", "log-plus-one", "atan2", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "clamp",
}
_SKIP_BYTES = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "while", "conditional", "call", "after-all", "add-dependency",
    "opt-barrier", "get-dimension-size", "partition-id", "replica-id",
    "iota",
}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_info(type_str: str):
    """(total_bytes, first_shape_dims, bytes_per_dtype) for a result
    type (maybe a tuple — each tuple element's bytes are attributed to
    its own dtype, so mixed u8-payload/f32-state carries split
    correctly)."""
    total = 0
    first = None
    per_dtype: dict[str, float] = {}
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in shape:
            n *= d
        total += n * DTYPE_BYTES[dt]
        per_dtype[dt] = per_dtype.get(dt, 0.0) + n * DTYPE_BYTES[dt]
        if first is None:
            first = shape
    return total, (first or []), per_dtype


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    out_bytes: int
    out_shape: List[int]
    operands: List[str]
    called: List[str]
    trip: int
    rest: str
    coll_kind: Optional[str] = None
    flops: float = 0.0
    out_dtype_bytes: Optional[dict] = None


def parse_module(hlo: str):
    comps: dict[str, dict[str, _Op]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        if line.rstrip().endswith("{"):
            mc = _COMP_RE.match(line)
            if mc:
                cur = mc.group(1)
                comps[cur] = {}
                if line.startswith("ENTRY"):
                    entry = cur
                continue
        mo = _OP_RE.match(line)
        if not mo or cur is None:
            continue
        name, type_str, kind, rest = mo.groups()
        out_bytes, out_shape, out_dtype_bytes = _shape_info(type_str)
        operands = re.findall(r"%([\w.\-]+)", rest.split(")")[0])
        called = _CALL_ATTR_RE.findall(rest)
        mb = _BRANCHES_RE.search(rest)
        if mb:
            called += re.findall(r"%([\w.\-]+)", mb.group(1))
        trip = 1
        if kind == "while":
            mt = _TRIP_RE.search(rest)
            trip = int(mt.group(1)) if mt else 1
        op = _Op(name, kind, out_bytes, out_shape, operands, called, trip,
                 rest, out_dtype_bytes=out_dtype_bytes)
        base = kind[:-6] if kind.endswith("-start") else kind
        if base in COLLECTIVES:
            op.coll_kind = base
        comps[cur][name] = op
    return comps, entry


def _dot_flops(op: _Op, table) -> float:
    n_out = 1
    for d in op.out_shape:
        n_out *= d
    csize = 1
    m = _CDIMS_RE.search(op.rest)
    if m and op.operands:
        lhs = table.get(op.operands[0])
        if lhs is not None:
            lshape = lhs.out_shape
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(lshape):
                    csize *= lshape[idx]
    return 2.0 * n_out * csize


def analyze(hlo: str) -> dict:
    comps, entry = parse_module(hlo)

    # per-op flops (dot needs the lhs symbol table of its computation)
    for cname, table in comps.items():
        for op in table.values():
            if op.kind == "dot":
                op.flops = _dot_flops(op, table)
            elif op.kind in _ELEMENTWISE_1:
                n = 1
                for d in op.out_shape:
                    n *= d
                op.flops = float(n)
            elif op.kind in ("reduce", "reduce-window"):
                src = table.get(op.operands[0]) if op.operands else None
                n = 1
                for d in (src.out_shape if src else []):
                    n *= d
                op.flops = float(n)
            elif op.kind == "convolution":
                # rare here; lower bound: 2 * output elements
                n = 1
                for d in op.out_shape:
                    n *= d
                op.flops = 2.0 * n

    # execution multiplier per computation (entry = 1); no recursion in HLO
    mult = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    idx = 0
    while idx < len(order):
        cname = order[idx]
        idx += 1
        for op in comps.get(cname, {}).values():
            factor = mult[cname] * (op.trip if op.kind == "while" else 1.0)
            for callee in op.called:
                fresh = callee not in mult
                mult[callee] += factor
                if fresh:
                    order.append(callee)

    flops = 0.0
    bytes_acc = 0.0
    by_dtype = defaultdict(float)
    coll = dict.fromkeys(COLLECTIVES, 0.0)
    coll_counts = dict.fromkeys(COLLECTIVES, 0.0)
    for cname, table in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        # region_* are scan bodies (real ops, counted); fused/wrapped
        # computations are thunk internals (boundary counted at callsite)
        in_fusion = "fused" in cname or cname.startswith("wrapped_")
        for op in table.values():
            flops += m * op.flops
            if op.coll_kind:
                factor = 2.0 if op.coll_kind == "all-reduce" else 1.0
                coll[op.coll_kind] += m * op.out_bytes * factor
                coll_counts[op.coll_kind] += m
            if in_fusion or op.kind in _SKIP_BYTES:
                continue
            # result bytes by dtype: makes the packed payload layer
            # visible (u8 buffers at width/8 B/elem — DESIGN.md §10);
            # tuple results split per element dtype
            for dt, b in (op.out_dtype_bytes or {}).items():
                by_dtype[dt] += m * b
            if op.kind in ("dynamic-slice", "slice", "gather"):
                # only the sliced window moves, not the whole operand
                bytes_acc += m * (2 * op.out_bytes)
            elif op.kind in ("dynamic-update-slice", "scatter"):
                # read+write of the updated window (operand[1]) + result ptr
                upd = (table[op.operands[1]].out_bytes
                       if len(op.operands) > 1 and op.operands[1] in table
                       else op.out_bytes)
                bytes_acc += m * (2 * upd)
            else:
                opnd = sum(table[o].out_bytes for o in op.operands
                           if o in table)
                bytes_acc += m * (op.out_bytes + opnd)
    return {"flops": flops, "bytes": bytes_acc, "coll_bytes": coll,
            "coll_counts": coll_counts, "coll_total": sum(coll.values()),
            "bytes_by_dtype": dict(by_dtype)}


# ---------------------------------------------------------------------------
# attribution: break down collective bytes / dot flops / big buffers by the
# jax op_name metadata — the "profiler" for dry-run hillclimbing.
# ---------------------------------------------------------------------------

_META_RE = re.compile(r'op_name="([^"]+)"')


def _tag(rest: str, depth: int = 4) -> str:
    m = _META_RE.search(rest)
    if not m:
        return "<no-metadata>"
    name = m.group(1)
    parts = name.split("/")
    return "/".join(parts[:depth])


def attribute(hlo: str, *, depth: int = 4, top: int = 20) -> dict:
    """Top contributors: collective bytes, dot flops, op output bytes."""
    comps, entry = parse_module(hlo)
    mult = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    i = 0
    while i < len(order):
        c = order[i]
        i += 1
        for op in comps.get(c, {}).values():
            f = mult[c] * (op.trip if op.kind == "while" else 1.0)
            for cal in op.called:
                fresh = cal not in mult
                mult[cal] += f
                if fresh:
                    order.append(cal)
    coll = defaultdict(float)
    dots = defaultdict(float)
    bufs = defaultdict(float)
    for cname, table in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in table.values():
            tag = None
            if op.coll_kind:
                tag = f"{op.coll_kind} <- {_tag(op.rest, depth)}"
                factor = 2.0 if op.coll_kind == "all-reduce" else 1.0
                coll[tag] += m * op.out_bytes * factor
            if op.kind == "dot":
                dots[_tag(op.rest, depth)] += m * _dot_flops(op, table)
            if op.out_bytes >= 1 << 20 and not (
                    "fused" in cname or cname.startswith("wrapped_")):
                bufs[f"{op.kind} <- {_tag(op.rest, depth)}"] += m * op.out_bytes

    def topk(d):
        return sorted(d.items(), key=lambda kv: -kv[1])[:top]

    return {"collectives": topk(coll), "dot_flops": topk(dots),
            "buffers": topk(bufs)}


# ---------------------------------------------------------------------------
# packed-pipeline footprints: the HBM/wire bytes-per-element each policy's
# GEMM operands occupy at rest under the packed payload layer (DESIGN.md
# §10) — what the codec refactor actually buys. Used by the examples'
# per-policy summaries and the wire-byte benchmark's memory gate.
# ---------------------------------------------------------------------------

def policy_packed_footprint(policy) -> dict:
    """Bytes per element of every GEMM operand under ``policy``.

    For MX policies this is the *packed* storage cost: element payload at
    ``width/8`` bytes plus one amortized E8M0 byte per group of 32
    (``MXFormat.packed_bytes_per_element``) — the layout the packed
    quantize kernel emits and the packed GEMM consumes, and the size of
    the activation residual saved for wgrad. For per-tensor/block fp8
    policies it is one byte plus the (negligible / 1-per-16Ki) scale
    overhead; unquantized policies pay the carrier dtype.

    Returns ``{"policy", "operands": {role: bytes_per_element},
    "residual_bpe", "fwd_wire_fraction_vs_bf16"}``.
    """
    import jax.numpy as jnp

    from ..core.formats import get_mx_format
    from ..core.policy import get_policy

    pol = get_policy(policy)
    out = {"policy": pol.name, "operands": {}}
    if pol.mx:
        roles = {
            "fwd_act": pol.mx_fwd, "fwd_w": pol.mx_fwd,
            "dgrad_grad": pol.mx_bwd_name, "dgrad_w": pol.mx_fwd,
            "wgrad_act": pol.mx_wgrad_act_name,
            "wgrad_grad": pol.mx_wgrad_grad_name,
            # attention KV tiles stream packed through the flash sweep
            # and double as the backward residuals (DESIGN.md §11)
            "attn_kv": pol.mx_attn_name,
            # the two remaining inter-chip wires (DESIGN.md §13): the
            # compressed DP gradient reduction and the MoE dispatch a2a
            "dp_grad": pol.mx_dp_grad,
            "moe_a2a": pol.mx_fwd,
        }
        out["operands"] = {r: get_mx_format(n).packed_bytes_per_element
                           for r, n in roles.items()}
        out["residual_bpe"] = out["operands"]["fwd_act"]
    elif pol.fwd_dtype is not None:
        scale_over = (4.0 / (pol.block_scale * pol.block_scale)
                      if pol.block_scale else 0.0)
        bpe_f = jnp.dtype(pol.fwd_dtype).itemsize + scale_over
        bpe_b = jnp.dtype(pol.bwd_dtype).itemsize + scale_over
        # attention stays at carrier precision outside the MX policies:
        # the per-tensor/block paths quantize GEMM operands only
        bpe_c = float(jnp.dtype(pol.compute_dtype).itemsize)
        out["operands"] = {"fwd_act": bpe_f, "fwd_w": bpe_f,
                           "dgrad_grad": bpe_b, "dgrad_w": bpe_f,
                           "wgrad_act": bpe_f, "wgrad_grad": bpe_b,
                           "attn_kv": bpe_c,
                           # per-leaf fp8 DP wire (one f32 scale/leaf);
                           # dispatch a2a stays at carrier width
                           "dp_grad": 1.0, "moe_a2a": bpe_c}
        out["residual_bpe"] = bpe_f
    else:
        bpe = float(jnp.dtype(pol.compute_dtype).itemsize)
        out["operands"] = {r: bpe for r in
                           ("fwd_act", "fwd_w", "dgrad_grad", "dgrad_w",
                            "wgrad_act", "wgrad_grad", "attn_kv",
                            "dp_grad", "moe_a2a")}
        out["residual_bpe"] = bpe
    out["fwd_wire_fraction_vs_bf16"] = out["operands"]["fwd_act"] / 2.0
    return out


def format_packed_footprint(policy) -> str:
    """One-block human summary of ``policy_packed_footprint`` for the
    example drivers."""
    fp = policy_packed_footprint(policy)
    ops_ = fp["operands"]
    lines = [f"[{fp['policy']}] packed operand footprint (bytes/element; "
             f"bf16 baseline = 2.0):"]
    for role in ("fwd_act", "fwd_w", "dgrad_grad", "dgrad_w",
                 "wgrad_act", "wgrad_grad", "attn_kv", "dp_grad",
                 "moe_a2a"):
        lines.append(f"  {role:<11} {ops_[role]:.5f}")
    lines.append(f"  residual    {fp['residual_bpe']:.5f}  "
                 f"(activation payload saved for wgrad)")
    lines.append(f"  fwd wire    {fp['fwd_wire_fraction_vs_bf16']:.3f}x "
                 f"of bf16 bytes")
    return "\n".join(lines)


def serve_cache_footprint(cfg, policy, max_len, page_size=16) -> dict:
    """Serving KV-cache bytes per sequence under ``policy``'s paged
    pool (DESIGN.md §12) vs the bf16 carrier baseline of the same
    geometry — what the packed page pool saves at decode time."""
    from ..core.policy import get_policy
    from ..serve.kv_cache import paged_kv_applicable, paged_kv_bytes_per_seq

    pol = get_policy(policy)
    packed = paged_kv_applicable(cfg, pol)
    bytes_seq = paged_kv_bytes_per_seq(cfg, pol, max_len,
                                       page_size=page_size)
    carrier = paged_kv_bytes_per_seq(cfg, get_policy("bf16"), max_len,
                                     page_size=page_size)
    return {"policy": pol.name,
            "cache_format": pol.mx_kv_cache_name if packed else
            "carrier-bf16",
            "max_len": max_len, "page_size": page_size,
            "cache_bytes_per_seq": bytes_seq,
            "bf16_bytes_per_seq": carrier,
            "compression_vs_bf16": carrier / bytes_seq}


def format_serve_cache_footprint(cfg, policy, max_len,
                                 page_size=16) -> str:
    """One-block human summary of ``serve_cache_footprint`` for the
    serving drivers."""
    fp = serve_cache_footprint(cfg, policy, max_len, page_size=page_size)
    return (f"[{fp['policy']}] serving KV cache ({fp['cache_format']}, "
            f"max_len={fp['max_len']}, page={fp['page_size']}): "
            f"{fp['cache_bytes_per_seq']} B/seq "
            f"({fp['compression_vs_bf16']:.2f}x smaller than bf16 "
            f"{fp['bf16_bytes_per_seq']} B/seq)")
