import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run profiler: compile one cell and attribute collective bytes, dot
FLOPs and large buffers to source ops — the measurement half of the
hypothesis -> change -> measure loop (EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.profile_cell --arch deepseek-7b \
        --shape train_4k [--multi-pod] [--depth 5]
"""
import argparse

import jax

from ..compat import set_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--depth", type=int, default=5)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    from ..configs import get_arch
    from ..configs.base import SHAPES
    from ..launch.mesh import make_production_mesh
    from ..launch.specs import build_cell, shardings_for
    from ..launch import hlo_analysis as H
    from ..optim.adamw import AdamWConfig

    cfg = get_arch(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    fn, cell_args, in_specs, donate, model, rules = build_cell(
        cfg, shape, mesh, opt_cfg=AdamWConfig(),
        microbatches=args.microbatches)
    with set_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=shardings_for(in_specs, mesh),
                           donate_argnums=donate).lower(*cell_args).compile()
    hlo = compiled.as_text()
    res = H.analyze(hlo)
    att = H.attribute(hlo, depth=args.depth, top=args.top)
    ma = compiled.memory_analysis()
    print(f"== {cfg.name} {shape.name} "
          f"{'pod2x16x16' if args.multi_pod else 'pod16x16'} ==")
    print(f"flops/dev {res['flops']:.3e}  bytes/dev {res['bytes']:.3e}  "
          f"coll/dev {res['coll_total']:.3e}")
    print(f"temp {ma.temp_size_in_bytes/2**30:.1f} GiB  "
          f"args {ma.argument_size_in_bytes/2**30:.1f} GiB")
    print("\n-- top collectives (bytes/device) --")
    for k, v in att["collectives"]:
        print(f"{v:12.3e}  {k}")
    print("\n-- top dot flops --")
    for k, v in att["dot_flops"]:
        print(f"{v:12.3e}  {k}")
    print("\n-- top buffers (bytes x executions) --")
    for k, v in att["buffers"]:
        print(f"{v:12.3e}  {k}")


if __name__ == "__main__":
    main()
