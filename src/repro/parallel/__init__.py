from .sharding import MeshRules, param_pspecs, batch_pspec, make_rules
