"""Explicit tensor-parallel GEMMs with narrow-wire collectives.

The paper's rule — *ship narrow, accumulate wide, round once* — applied to
the TP/SP/ZeRO interconnect (§Perf D5/D6, the flagship beyond-paper
optimization). Fully-manual shard_map over (batch-axes..., model):

  column-parallel (QKV / MLP-in), x sequence-sharded:
    fwd:  quantize local -> **fp8 all-gather** of activations (4x less wire
          than the f32 gathers GSPMD emits) -> dequant -> f32-accum GEMM
    bwd:  grads quantize to E5M2; dgrad partials ship **bf16 all-to-all**
          and accumulate **f32 locally** (wire of a reduce-scatter, the
          numerics of an ExSdotp chain across chips); wgrad contracts
          locally and reduce-scatters over the data axis the same
          narrow-wire way — this *is* the ZeRO gradient reduction.

  row-parallel (attn-out / MLP-down), input model-sharded on features:
    fwd:  local GEMM -> bf16 a2a + f32 local sum -> sequence-sharded out
    bwd:  fp8-E5M2 gather of grads; dgrad local; wgrad as above.

(XLA CPU aborts on bf16 wire-reduce collectives, and a wire-reduce would
accumulate narrow anyway — a2a + local f32 sum is both portable and
numerically stronger.)

FSDP weight shards are all-gathered bf16 inside (tiny vs activations).
Everything else in the model stays under GSPMD; boundaries are layout
no-ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from ..compat import shard_map
from jax.sharding import PartitionSpec as P

from ..core.formats import e8m0_decode, e8m0_encode, get_mx_format
from ..core.policy import Policy
from ..core.scaling import (BlockScaleConfig, apply_block_scales,
                            apply_group_scales, compute_block_scales,
                            compute_group_scales)
from ..kernels.codec import get_codec

__all__ = ["tp_column_linear", "tp_row_linear", "tp_applicable",
           "row_applicable", "make_fsdp_gather", "embed_lookup_ep",
           "embed_ep_applicable", "mx_dispatch_a2a"]


def _quant_local(x, dtype):
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    # non-finite amax -> scale 1: inf/NaN propagate instead of an inf
    # scale flushing the shard to zero (mirrors ops.quantize_tensor)
    s = jnp.where((amax > 0) & jnp.isfinite(amax),
                  amax / jnp.float32(jnp.finfo(dtype).max), 1.0)
    return (xf / s).astype(dtype), s


# ------------------------------------------------- block-scaled wire ------
# The paper's recipe survives the interconnect at *block* granularity:
# each shard quantizes per-(row-tile × K-tile) block and ships the fp8
# payload with its tiny f32 scale grid riding along (one 4-byte scale
# per block_m*block_k 1-byte payload elements: ~1/4096 of wire bytes at
# the default 128); receivers dequantize per block before the f32
# accumulation, so the ExSdotp structure — and the per-block outlier
# robustness of DESIGN.md §3 — both hold across chips.

def _fit_block(dim: int, pref: int) -> int:
    """Largest tile size <= pref that divides ``dim``.  Shapes inside
    shard_map are shard-local and concrete, so this runs at trace time;
    shard boundaries then always coincide with tile boundaries, and
    finer-than-configured tiles only tighten the scales."""
    b = max(1, min(pref, dim))
    while dim % b:
        b -= 1
    return b


def _quant_block(x, dtype, cfg: BlockScaleConfig, pref_r: int, pref_c: int):
    """Block-quantize ``x[..., R, C]``: per-(leading index, R-tile ×
    C-tile) scales.  Returns ``(q, scales, (br, bc))`` — the scale grid
    is what rides the wire next to the fp8 payload."""
    br = _fit_block(x.shape[-2], pref_r)
    bc = _fit_block(x.shape[-1], pref_c)
    xf = x.astype(jnp.float32)
    s = compute_block_scales(xf, br, bc, dtype,
                             margin=cfg.margin, pow2=cfg.pow2)
    q = apply_block_scales(xf, s, br, bc, inverse=True).astype(dtype)
    return q, s, (br, bc)


def _deq_block(q, s, br, bc):
    """Dequantize at accumulator granularity: every element is rescaled
    by its own block's factor *before* the f32 contraction, so the fp32
    accumulator sees exactly the blockscale_gemm_ref math."""
    return apply_block_scales(q.astype(jnp.float32), s, br, bc)


# ------------------------------------------------- MX wire (§9/§10) ------
# MX policies ride the wire natively: the payload ships at its true
# width — fp8 elements in their native one-byte dtype, sub-byte
# elements (MXFP6/4) as *packed* uint8 lanes via the payload codec
# (width/8 bytes per element) — next to a *packed E8M0 byte grid*, one
# uint8 code per group of 32 (~1/32 of payload bytes; vs 4-byte f32
# scales, 4x less scale traffic).  The receiver unpacks/decodes the
# payload and the grid (both exact) and dequantizes per group *before*
# the f32 accumulation, so the per-group ExSdotp structure of
# DESIGN.md §8 holds across chips.

def _mx_wire_packed(mx) -> bool:
    """Sub-byte element formats ship packed codec lanes; fp8 elements
    ship their native one-byte dtype (same bytes, zero decode cost)."""
    return mx.elem.width < 8 or mx.elem.ml_dtype is None


def _quant_mx(x, mx):
    """MX-quantize ``x[..., K]`` for the wire: groups of ``mx.group``
    along the last axis, E8M0 pow2 scales.  Returns ``(payload, s8)``
    — the payload in the element format's native one-byte dtype (fp8;
    the cast is bit-identical to the value-space ``formats.quantize``)
    or as densely packed uint8 lanes (sub-byte formats — FP4 ships two
    elements per byte, FP6 four in three) — and the uint8 E8M0 codes.
    A non-finite group gets the NaN scale (0xFF): payload and decoded
    scale both read back NaN — the §8 poison convention survives the
    byte grid (sub-byte payloads have no NaN encoding; the grid alone
    carries it).
    """
    xf = x.astype(jnp.float32)
    s = compute_group_scales(xf, mx.group, mx.elem.max_normal)
    q = apply_group_scales(xf, s, mx.group, inverse=True)
    if _mx_wire_packed(mx):
        payload = get_codec(mx).encode_lanes(q)
    else:
        payload = q.astype(mx.elem.ml_dtype)
    return payload, e8m0_encode(s)


def _deq_mx(q, s8, mx):
    """Unpack/decode the payload and the E8M0 byte grid and rescale per
    group — exact (pow2), at accumulator granularity like
    ``_deq_block``."""
    if q.dtype == jnp.uint8:
        vals = get_codec(mx).decode_lanes(q)
    else:
        vals = q.astype(jnp.float32)
    return apply_group_scales(vals, e8m0_decode(s8), mx.group)


def _a2a_sum(partial_f32, axis, n, dim, wire_dtype=jnp.bfloat16, cfg=None,
             mx=None):
    """Ship narrow partials all-to-all along ``dim``, accumulate f32.

    With ``wire_dtype`` fp8 (§Perf D8), each source quantizes its partial
    with a private scale that rides along (n floats) — the wire halves
    again and the receiver still accumulates f32 (ExSdotp on the wire,
    now at the paper's own operand width).

    With ``cfg`` (a ``BlockScaleConfig``) and an fp8 wire, quantization
    is per-(row-tile × col-tile) block on the last two dims instead of
    per-shard-tensor: the scale *grids* ride the a2a alongside the
    payload, and each receiver dequantizes per block before the f32 sum
    — the block-scaled subsystem's outlier robustness on the wire.
    Requires ``dim`` to be the row axis (ndim-2).

    With ``mx`` (an ``MXFormat``, DESIGN.md §9), quantization is
    per-(row × group-of-32) along the *last* axis: the one-byte payload
    ships with its packed E8M0 byte grid (one uint8 per group, ~1/32 of
    payload bytes), and each receiver decodes + dequantizes per group
    before the f32 sum.  Falls back to the bf16 wire when the last axis
    doesn't tile into whole groups, or — when ``dim`` is the last axis
    itself — when the split doesn't land on group boundaries (the grid
    must split with the payload).
    """
    sh = partial_f32.shape
    split = sh[dim] // n
    if mx is not None and sh[-1] % mx.group == 0 and (
            dim != partial_f32.ndim - 1 or split % mx.group == 0):
        # a split on the last (packed) axis lands on group boundaries
        # (gated above), and a whole group is a whole number of packed
        # bytes for every codec (32·w/8 ∈ {16, 24, 32} B) — so payload
        # and grid always split along byte/code boundaries and the
        # reshapes below follow each array's own last-axis length
        q, s8 = _quant_mx(partial_f32, mx)
        if dim == partial_f32.ndim - 1:
            qp = q.reshape(*q.shape[:-1], n, q.shape[-1] // n)
            sp = s8.reshape(*s8.shape[:-1], n, s8.shape[-1] // n)
        else:
            qp = q.reshape(*q.shape[:dim], n, split, *q.shape[dim + 1:])
            sp = s8.reshape(*s8.shape[:dim], n, split, *s8.shape[dim + 1:])
        recv = jax.lax.all_to_all(qp, axis, split_axis=dim,
                                  concat_axis=dim, tiled=True)
        srecv = jax.lax.all_to_all(sp, axis, split_axis=dim,
                                   concat_axis=dim, tiled=True)
        return jnp.sum(_deq_mx(recv, srecv, mx), axis=dim)
    if cfg is not None and jnp.dtype(wire_dtype).itemsize == 1:
        assert dim == partial_f32.ndim - 2, (dim, sh)
        br = _fit_block(split, cfg.block_m)
        bc = _fit_block(sh[-1], cfg.block_n)
        q, s, _ = _quant_block(partial_f32, wire_dtype, cfg, br, bc)
        qp = q.reshape(*sh[:dim], n, split, sh[-1])
        sp = s.reshape(*s.shape[:-2], n, split // br, s.shape[-1])
        recv = jax.lax.all_to_all(qp, axis, split_axis=dim,
                                  concat_axis=dim, tiled=True)
        srecv = jax.lax.all_to_all(sp, axis, split_axis=dim,
                                   concat_axis=dim, tiled=True)
        return jnp.sum(_deq_block(recv, srecv, br, bc), axis=dim)
    if jnp.dtype(wire_dtype).itemsize == 1:
        amax = jnp.max(jnp.abs(partial_f32))
        s = jnp.where((amax > 0) & jnp.isfinite(amax),
                      amax / jnp.float32(jnp.finfo(wire_dtype).max), 1.0)
        yp = (partial_f32 / s).astype(wire_dtype).reshape(
            *sh[:dim], n, split, *sh[dim + 1:])
        recv = jax.lax.all_to_all(yp, axis, split_axis=dim,
                                  concat_axis=dim, tiled=True)
        ss = jax.lax.all_gather(s.reshape(1), axis, axis=0, tiled=True)
        shape_bc = [1] * recv.ndim
        shape_bc[dim] = n
        return jnp.sum(recv.astype(jnp.float32)
                       * ss.reshape(shape_bc), axis=dim)
    yp = partial_f32.astype(wire_dtype).reshape(
        *sh[:dim], n, split, *sh[dim + 1:])
    recv = jax.lax.all_to_all(yp, axis, split_axis=dim, concat_axis=dim,
                              tiled=True)
    return jnp.sum(recv.astype(jnp.float32), axis=dim)


def _mx_a2a_wire(x, axis, mx):
    """One packed resharding hop: quantize groups of ``mx.group`` along
    the last axis, all-to-all payload and E8M0 byte grid over ``axis``
    (split/concat on axis 0, tiled — the MoE dispatch permutation),
    dequantize on the receive side.  The a2a splits axis 0 while the
    groups live on the last axis, so payload ([..., d·w/8] bytes) and
    grid ([..., d/32] codes) reshard identically and no group is ever
    cut."""
    q, s8 = _quant_mx(x, mx)
    qr = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0,
                            tiled=True)
    sr = jax.lax.all_to_all(s8, axis, split_axis=0, concat_axis=0,
                            tiled=True)
    return _deq_mx(qr, sr, mx)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def mx_dispatch_a2a(x, axis, mx_fwd, mx_bwd):
    """MoE dispatch all-to-all on the packed MX wire (DESIGN.md §13).

    Reshards ``x[s, ...]`` over mesh axis ``axis`` (split axis 0, concat
    axis 0, tiled — exactly ``jax.lax.all_to_all``'s dispatch shape) but
    ships packed codec payloads + E8M0 group grids instead of the
    carrier tensor: groups of 32 along the last (``d_model``) axis,
    quantize before the wire, dequantize after.  Not a reduction — each
    destination receives whole rows — so unlike ``_a2a_sum`` there is no
    accumulate, just decode.

    ``custom_vjp`` because the packed wire is built from bitcasts and
    uint8 lane ops autodiff can't see through, and because the backward
    wire wants its *own* element format: the cotangent rides the reverse
    all-to-all (the tiled split-0/concat-0 a2a is a block permutation
    and hence its own transpose) quantized as ``mx_bwd`` — gradients are
    the range-hungry side, same asymmetry as the GEMM operands.  Callers
    gate on ``x.shape[-1] % 32 == 0`` and fall back to the raw carrier
    a2a otherwise.
    """
    return _mx_a2a_wire(x, axis, mx_fwd).astype(x.dtype)


def _mx_dispatch_fwd(x, axis, mx_fwd, mx_bwd):
    # residual leaves must be jax values: carry the input dtype as a
    # zero-size array, not a dtype object
    return (mx_dispatch_a2a(x, axis, mx_fwd, mx_bwd),
            jnp.zeros((0,), x.dtype))


def _mx_dispatch_bwd(axis, mx_fwd, mx_bwd, proto, g):
    return (_mx_a2a_wire(g.astype(jnp.float32), axis, mx_bwd)
            .astype(proto.dtype),)


mx_dispatch_a2a.defvjp(_mx_dispatch_fwd, _mx_dispatch_bwd)


def _grad_reduce_data(dw_f32, rules, dim: int = 0, mx=None):
    """ZeRO gradient reduction over the data axis: bf16 a2a + f32 local
    accumulation, landing FSDP-sharded on ``dim`` (matches the param
    spec); plus an f32 psum over the pod axis when present.  With ``mx``
    the a2a ships the fp8-payload + E8M0-byte-grid wire instead (§9)."""
    n = rules.mesh.shape[rules.fsdp_axis]
    dw = _a2a_sum(dw_f32, rules.fsdp_axis, n, dim, mx=mx)
    if "pod" in rules.mesh.axis_names:
        dw = jax.lax.psum(dw, "pod")
    return dw


def _axes(rules):
    ba = rules.batch_axes
    return ba, rules.model_axis, rules.model_size


def make_fsdp_gather(rules, dim: int):
    """ZeRO-3 weight gather for use INSIDE manual shard_map regions:
    bf16 all-gather forward; backward = the narrow-wire gradient
    reduce-scatter (bf16 a2a + f32 local accumulation, f32 psum across
    pods). Avoids jax's default transpose (bf16 psum_scatter), which both
    accumulates narrow and aborts XLA CPU."""
    axis = rules.fsdp_axis
    n = rules.mesh.shape[axis]

    @jax.custom_vjp
    def g(w):
        return jax.lax.all_gather(w, axis, axis=dim, tiled=True)

    def fwd(w):
        return g(w), None

    def bwd(_, ct):
        dw = _a2a_sum(ct.astype(jnp.float32), axis, n, dim)
        if "pod" in rules.mesh.axis_names:
            dw = jax.lax.psum(dw, "pod")
        return (dw.astype(ct.dtype),)

    g.defvjp(fwd, bwd)
    return g


def tp_applicable(x, rules, policy: Policy) -> bool:
    if rules is None or rules.mesh is None or not rules.seq_shard:
        return False
    if not getattr(policy, "quantized", False) or x.ndim != 3:
        return False
    if getattr(policy, "mx_fwd", ""):
        # MX policies ride the wire natively (DESIGN.md §9/§10): narrow
        # payloads (native fp8 bytes, or packed sub-byte codec lanes
        # for MXFP6/4) + packed E8M0 byte grids on every collective —
        # provided the group structure survives the sharding.  Groups
        # run along contraction axes: K (fwd), N-shards (dgrad) and the
        # token axis (wgrad), so the feature dim and the sequence dim
        # must both tile into whole groups.  A whole group is a whole
        # number of packed bytes for every codec, so group alignment
        # subsumes pack alignment on the wire.  All four operand
        # formats (fwd/bwd/wgrad pair) must share the group size.
        fmts = [get_mx_format(n) for n in
                (policy.mx_fwd, policy.mx_bwd_name,
                 policy.mx_wgrad_act_name, policy.mx_wgrad_grad_name)]
        fwd = fmts[0]
        if len({f.group for f in fmts}) != 1:
            return False
        if x.shape[-1] % fwd.group or x.shape[1] % fwd.group:
            return False
    if rules.fsdp_axis not in rules.mesh.axis_names:
        return False
    tp = rules.model_size
    dp = 1
    for a in rules.batch_axes:
        dp *= rules.mesh.shape[a]
    return (tp > 1 and x.shape[1] % tp == 0 and x.shape[1] >= tp
            and x.shape[0] % dp == 0)


row_applicable = tp_applicable  # same preconditions (checked on block input)


# ---------------------------------------------------------------- column --

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def tp_column_linear(x, w, policy: Policy, rules):
    y, _ = _tp_col_fwd(x, w, policy, rules)
    return y


def _tp_col_fwd(x, w, policy, rules):
    if getattr(policy, "mx_fwd", ""):
        return _tp_col_fwd_mx(x, w, policy, rules)
    if policy.block_cfg is not None:
        return _tp_col_fwd_block(x, w, policy, rules)
    ba, axis, tp = _axes(rules)
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, axis, None), P(rules.fsdp_axis, axis)),
        out_specs=(P(ba, None, axis), P(ba, axis, None), P(ba + (axis,))),
        axis_names=manual, check_vma=False)
    def fwd(xl, wl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=0, tiled=True)
        xq, sx = _quant_local(xl, policy.fwd_dtype)
        wq, sw = _quant_local(wg, policy.fwd_dtype)
        xg = jax.lax.all_gather(xq, axis, axis=1, tiled=True)   # fp8 wire
        ss = jax.lax.all_gather(sx.reshape(1), axis, axis=0, tiled=True)
        sx_full = jnp.repeat(ss, xl.shape[1])[None, :, None]
        y = jnp.dot(xg.astype(jnp.float32) * sx_full,
                    wq.astype(jnp.float32) * sw,
                    preferred_element_type=jnp.float32)
        return y.astype(cd), xq, (sx * sw).reshape(1)

    # residuals: the *local* fp8 activations + combined scale (weights are
    # cheap to re-quantize in bwd; activations are not)
    y, xq, sxw = fwd(x, w)
    return y, (xq, sxw, w)


def _tp_col_bwd(policy, rules, res, g):
    if getattr(policy, "mx_fwd", ""):
        return _tp_col_bwd_mx(policy, rules, res, g)
    if policy.block_cfg is not None:
        return _tp_col_bwd_block(policy, rules, res, g)
    ba, axis, tp = _axes(rules)
    xq, sxw, w = res
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, axis, None), P(ba + (axis,)),
                  P(rules.fsdp_axis, axis), P(ba, None, axis)),
        out_specs=(P(ba, axis, None), P(rules.fsdp_axis, axis)),
        axis_names=manual, check_vma=False)
    def bwd(xql, sxwl, wl, gl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=0, tiled=True)
        wq, sw = _quant_local(wg, policy.fwd_dtype)
        gq, sg = _quant_local(gl, policy.bwd_dtype)              # E5M2
        gf = gq.astype(jnp.float32) * sg
        # dgrad: partial over model (N split) -> back to seq shards
        dpart = jnp.dot(gf, (wq.astype(jnp.float32) * sw).T,
                        preferred_element_type=jnp.float32)
        dx = _a2a_sum(dpart, axis, tp, 1).astype(cd)
        # wgrad: re-gather fp8 activations; contract local tokens; then
        # narrow-wire ZeRO reduce-scatter over data
        xg = jax.lax.all_gather(xql, axis, axis=1, tiled=True)
        ss = jax.lax.all_gather(sxwl, axis, axis=0, tiled=True)
        # sxwl = sx*sw; undo sw so x dequantizes correctly
        sxf = jnp.repeat(ss / sw, xql.shape[1])[None, :, None]
        dwl = jnp.einsum("bsk,bsn->kn", xg.astype(jnp.float32) * sxf, gf,
                         preferred_element_type=jnp.float32)
        dw = _grad_reduce_data(dwl, rules).astype(cd)
        return dx, dw

    dx, dw = bwd(xq, sxw, w, g)
    return dx, dw


def _tp_col_fwd_block(x, w, policy, rules):
    """Column-parallel forward, block-scaled wire (DESIGN.md §3 × §4).

    Each sequence shard quantizes its activations per-(batch, seq-tile ×
    K-tile) block; the fp8 payload is all-gathered over the model axis
    with the f32 scale grid gathered alongside (gathering shard grids
    along the seq axis reassembles exactly the full-tensor grid, tiles
    aligned to shard boundaries).  The receiver dequantizes per block
    and contracts in f32 — per-block ExSdotp across chips.
    """
    ba, axis, tp = _axes(rules)
    cfg = policy.block_cfg
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, axis, None), P(rules.fsdp_axis, axis)),
        out_specs=(P(ba, None, axis), P(ba, axis, None), P(ba, axis, None)),
        axis_names=manual, check_vma=False)
    def fwd(xl, wl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=0, tiled=True)
        xq, sx, (bs, bk) = _quant_block(xl, policy.fwd_dtype, cfg,
                                        cfg.block_m, cfg.block_k)
        wq, sw, (bkw, bn) = _quant_block(wg, policy.fwd_dtype, cfg,
                                         cfg.block_k, cfg.block_n)
        xg = jax.lax.all_gather(xq, axis, axis=1, tiled=True)   # fp8 wire
        sg = jax.lax.all_gather(sx, axis, axis=1, tiled=True)   # scale grid
        y = jnp.einsum("bsk,kn->bsn",
                       _deq_block(xg, sg, bs, bk),
                       _deq_block(wq, sw, bkw, bn),
                       preferred_element_type=jnp.float32)
        return y.astype(cd), xq, sx

    # residuals: local fp8 activations + their scale grid (weights are
    # cheap to re-quantize in bwd; activations are not)
    y, xq, sx = fwd(x, w)
    return y, (xq, sx, w)


def _tp_col_bwd_block(policy, rules, res, g):
    ba, axis, tp = _axes(rules)
    cfg = policy.block_cfg
    xq, sx, w = res
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, axis, None), P(ba, axis, None),
                  P(rules.fsdp_axis, axis), P(ba, None, axis)),
        out_specs=(P(ba, axis, None), P(rules.fsdp_axis, axis)),
        axis_names=manual, check_vma=False)
    def bwd(xql, sxl, wl, gl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=0, tiled=True)
        wq, sw, (bkw, bn) = _quant_block(wg, policy.fwd_dtype, cfg,
                                         cfg.block_k, cfg.block_n)
        gq, sg, (bsg, bng) = _quant_block(gl, policy.bwd_dtype, cfg,
                                          cfg.block_m, cfg.block_n)  # E5M2
        gf = _deq_block(gq, sg, bsg, bng)
        wf = _deq_block(wq, sw, bkw, bn)
        # dgrad: partial over model (N split) -> back to seq shards
        dpart = jnp.einsum("bsn,kn->bsk", gf, wf,
                           preferred_element_type=jnp.float32)
        dx = _a2a_sum(dpart, axis, tp, 1).astype(cd)
        # wgrad: re-gather fp8 activations + their scale grids; contract
        # local tokens; then narrow-wire ZeRO reduce-scatter over data
        xg = jax.lax.all_gather(xql, axis, axis=1, tiled=True)
        ssg = jax.lax.all_gather(sxl, axis, axis=1, tiled=True)
        bs = xql.shape[1] // sxl.shape[1]
        bk = xql.shape[2] // sxl.shape[2]
        dwl = jnp.einsum("bsk,bsn->kn", _deq_block(xg, ssg, bs, bk), gf,
                         preferred_element_type=jnp.float32)
        dw = _grad_reduce_data(dwl, rules).astype(cd)
        return dx, dw

    dx, dw = bwd(xq, sx, w, g)
    return dx, dw


def _tp_col_fwd_mx(x, w, policy, rules):
    """Column-parallel forward, MX wire (DESIGN.md §9 = §8 × §4).

    Each sequence shard MX-quantizes its activations per-(row ×
    group-of-32-along-K) — exactly the single-device ``ops.mx_gemm``
    granularity, since groups run along the unsharded K axis — and
    all-gathers the one-byte payload over the model axis with the
    packed E8M0 byte grid riding along (~1/32 of payload bytes).  The
    receiver decodes + dequantizes per group (exact — pow2) and
    contracts in f32: per-group ExSdotp across chips, numerically
    identical to the GSPMD-sharded fused MX GEMM.
    """
    ba, axis, tp = _axes(rules)
    mxf = get_mx_format(policy.mx_fwd)
    g = mxf.group
    if (w.shape[1] // tp) % g:
        # dgrad groups run along the local N columns; tp_applicable
        # can't see w, so direct callers fail fast here (proj() routes
        # such shapes to the GSPMD fallback instead)
        raise ValueError(
            f"MX TP column GEMM needs N/tp divisible by the group: "
            f"N={w.shape[1]}, tp={tp}, group={g}")
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, axis, None), P(rules.fsdp_axis, axis)),
        out_specs=(P(ba, None, axis), P(ba, axis, None), P(ba, axis, None)),
        axis_names=manual, check_vma=False)
    def fwd(xl, wl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=0, tiled=True)
        xq, sx8 = _quant_mx(xl, mxf)                  # groups along K
        wq, sw8 = _quant_mx(wg.T, mxf)                # w columns, along K
        xg = jax.lax.all_gather(xq, axis, axis=1, tiled=True)   # narrow wire
        sg8 = jax.lax.all_gather(sx8, axis, axis=1, tiled=True)  # E8M0 bytes
        y = jnp.einsum("bsk,kn->bsn",
                       _deq_mx(xg, sg8, mxf),
                       _deq_mx(wq, sw8, mxf).T,
                       preferred_element_type=jnp.float32)
        return y.astype(cd), xq, sx8

    # residuals: local narrow payload + its E8M0 byte grid (weights are
    # cheap to re-quantize in bwd; activations are not)
    y, xq, sx8 = fwd(x, w)
    return y, (xq, sx8, w)


def _tp_col_bwd_mx(policy, rules, res, g_ct):
    """dgrad: grads and weights re-quantize per-group along the local N
    columns (shard boundaries coincide with group boundaries — the
    ``tp_applicable`` divisibility gate), partials ship over the MX
    a2a wire.  wgrad: the fwd payload is re-gathered (packed bytes +
    byte grid), dequantized, and both operands re-quantize per-group
    along the *token* axis — the single-device wgrad grouping, in the
    policy's wgrad formats (``mx_wgrad_*``: the FP8 master-wgrad pair
    for the sub-byte policies) — with the raw local cotangent used for
    the grad operand (no double rounding on g; x carries the one fwd
    rounding the narrow wire implies, exactly like the per-tensor
    path).  The ZeRO data reduction ships the same narrow + E8M0
    wire."""
    ba, axis, tp = _axes(rules)
    mxf = get_mx_format(policy.mx_fwd)
    mxb = get_mx_format(policy.mx_bwd_name)
    mxwa = get_mx_format(policy.mx_wgrad_act_name)
    mxwg = get_mx_format(policy.mx_wgrad_grad_name)
    g = mxf.group
    xq, sx8, w = res
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, axis, None), P(ba, axis, None),
                  P(rules.fsdp_axis, axis), P(ba, None, axis)),
        out_specs=(P(ba, axis, None), P(rules.fsdp_axis, axis)),
        axis_names=manual, check_vma=False)
    def bwd(xql, sx8l, wl, gl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=0, tiled=True)
        # dgrad: contract over the local N columns; groups along N
        gq, sg8 = _quant_mx(gl, mxb)                  # [B, S, Nl], bwd fmt
        wqn, swn8 = _quant_mx(wg, mxf)                # w rows, along Nl
        gf = _deq_mx(gq, sg8, mxb)
        dpart = jnp.einsum("bsn,kn->bsk", gf, _deq_mx(wqn, swn8, mxf),
                           preferred_element_type=jnp.float32)
        dx = _a2a_sum(dpart, axis, tp, 1, mx=mxb).astype(cd)
        # wgrad: re-gather the packed payload + byte grid; both operands
        # re-group along the contracted token axis in the wgrad formats
        xg = jax.lax.all_gather(xql, axis, axis=1, tiled=True)
        sxg8 = jax.lax.all_gather(sx8l, axis, axis=1, tiled=True)
        xf = _deq_mx(xg, sxg8, mxf)                   # [B, S, K] f32
        xqt, sxt8 = _quant_mx(xf.transpose(0, 2, 1), mxwa)  # [B, K, S]
        gqt, sgt8 = _quant_mx(gl.transpose(0, 2, 1), mxwg)  # [B, Nl, S]
        dwl = jnp.einsum("bks,bns->kn",
                         _deq_mx(xqt, sxt8, mxwa), _deq_mx(gqt, sgt8, mxwg),
                         preferred_element_type=jnp.float32)
        dw = _grad_reduce_data(dwl, rules, mx=mxwg).astype(cd)
        return dx, dw

    dx, dw = bwd(xq, sx8, w, g_ct)
    return dx, dw


tp_column_linear.defvjp(_tp_col_fwd, _tp_col_bwd)


# ------------------------------------------------------------------- row --

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def tp_row_linear(x, w, policy: Policy, rules):
    y, _ = _tp_row_fwd(x, w, policy, rules)
    return y


def _tp_row_fwd(x, w, policy, rules):
    if getattr(policy, "mx_fwd", ""):
        return _tp_row_fwd_mx(x, w, policy, rules)
    if policy.block_cfg is not None:
        return _tp_row_fwd_block(x, w, policy, rules)
    ba, axis, tp = _axes(rules)
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, None, axis), P(axis, rules.fsdp_axis)),
        out_specs=(P(ba, axis, None), P(ba, None, axis), P(ba + (axis,))),
        axis_names=manual, check_vma=False)
    def fwd(xl, wl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=1, tiled=True)
        xq, sx = _quant_local(xl, policy.fwd_dtype)
        wq, sw = _quant_local(wg, policy.fwd_dtype)
        part = jnp.dot(xq.astype(jnp.float32) * sx,
                       wq.astype(jnp.float32) * sw,
                       preferred_element_type=jnp.float32)
        # D8: forward activations ship at the paper's operand width (fp8,
        # per-source scales); the receiver accumulates f32. Gradient-path
        # reductions stay bf16 (one fewer rounding on the sensitive path).
        y = _a2a_sum(part, axis, tp, 1, wire_dtype=policy.fwd_dtype)
        return y.astype(cd), xq, sx.reshape(1)

    y, xq, sx = fwd(x, w)
    return y, (xq, sx, w)


def _tp_row_bwd(policy, rules, res, g):
    if getattr(policy, "mx_fwd", ""):
        return _tp_row_bwd_mx(policy, rules, res, g)
    if policy.block_cfg is not None:
        return _tp_row_bwd_block(policy, rules, res, g)
    ba, axis, tp = _axes(rules)
    xq, sx, w = res
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, None, axis), P(ba + (axis,)),
                  P(axis, rules.fsdp_axis), P(ba, axis, None)),
        out_specs=(P(ba, None, axis), P(axis, rules.fsdp_axis)),
        axis_names=manual, check_vma=False)
    def bwd(xql, sxl, wl, gl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=1, tiled=True)
        wq, sw = _quant_local(wg, policy.fwd_dtype)
        gq, sg = _quant_local(gl, policy.bwd_dtype)              # E5M2
        gg = jax.lax.all_gather(gq, axis, axis=1, tiled=True)    # fp8 wire
        ss = jax.lax.all_gather(sg.reshape(1), axis, axis=0, tiled=True)
        sgf = jnp.repeat(ss, gl.shape[1])[None, :, None]
        gf = gg.astype(jnp.float32) * sgf                        # [B,S,K]
        dx = jnp.dot(gf, (wq.astype(jnp.float32) * sw).T,
                     preferred_element_type=jnp.float32).astype(cd)
        dwl = jnp.einsum("bsn,bsk->nk",
                         xql.astype(jnp.float32) * sxl[0], gf,
                         preferred_element_type=jnp.float32)
        # ZeRO reduce over data lands on dim1 (w is [N_model, K_fsdp])
        dw = _grad_reduce_data(dwl, rules, dim=1)
        return dx, dw.astype(cd)

    dx, dw = bwd(xq, sx, w, g)
    return dx, dw


def _tp_row_fwd_block(x, w, policy, rules):
    """Row-parallel forward, block-scaled wire: local per-block GEMM,
    then the partial products themselves ship fp8 all-to-all with their
    scale grids riding along (``_a2a_sum(cfg=...)``) — the receiver
    dequantizes per block and accumulates f32 locally."""
    ba, axis, tp = _axes(rules)
    cfg = policy.block_cfg
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, None, axis), P(axis, rules.fsdp_axis)),
        out_specs=(P(ba, axis, None), P(ba, None, axis), P(ba, None, axis)),
        axis_names=manual, check_vma=False)
    def fwd(xl, wl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=1, tiled=True)
        xq, sx, (bs, bk) = _quant_block(xl, policy.fwd_dtype, cfg,
                                        cfg.block_m, cfg.block_k)
        wq, sw, (bkw, bn) = _quant_block(wg, policy.fwd_dtype, cfg,
                                         cfg.block_k, cfg.block_n)
        part = jnp.einsum("bsk,kn->bsn",
                          _deq_block(xq, sx, bs, bk),
                          _deq_block(wq, sw, bkw, bn),
                          preferred_element_type=jnp.float32)
        # D8 at block granularity: forward partials ship at the paper's
        # operand width with per-block scales; gradient-path reductions
        # stay bf16 (one fewer rounding on the sensitive path).
        y = _a2a_sum(part, axis, tp, 1, wire_dtype=policy.fwd_dtype,
                     cfg=cfg)
        return y.astype(cd), xq, sx

    y, xq, sx = fwd(x, w)
    return y, (xq, sx, w)


def _tp_row_bwd_block(policy, rules, res, g):
    ba, axis, tp = _axes(rules)
    cfg = policy.block_cfg
    xq, sx, w = res
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, None, axis), P(ba, None, axis),
                  P(axis, rules.fsdp_axis), P(ba, axis, None)),
        out_specs=(P(ba, None, axis), P(axis, rules.fsdp_axis)),
        axis_names=manual, check_vma=False)
    def bwd(xql, sxl, wl, gl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=1, tiled=True)
        wq, sw, (bkw, bn) = _quant_block(wg, policy.fwd_dtype, cfg,
                                         cfg.block_k, cfg.block_n)
        gq, sg, (bsg, bng) = _quant_block(gl, policy.bwd_dtype, cfg,
                                          cfg.block_m, cfg.block_n)  # E5M2
        gg = jax.lax.all_gather(gq, axis, axis=1, tiled=True)   # fp8 wire
        ssg = jax.lax.all_gather(sg, axis, axis=1, tiled=True)  # scale grid
        gf = _deq_block(gg, ssg, bsg, bng)                      # [B,S,N] f32
        wf = _deq_block(wq, sw, bkw, bn)
        dx = jnp.einsum("bsn,kn->bsk", gf, wf,
                        preferred_element_type=jnp.float32).astype(cd)
        bs = xql.shape[1] // sxl.shape[1]
        bk = xql.shape[2] // sxl.shape[2]
        dwl = jnp.einsum("bsk,bsn->kn", _deq_block(xql, sxl, bs, bk), gf,
                         preferred_element_type=jnp.float32)
        # ZeRO reduce over data lands on dim1 (w is [N_model, K_fsdp])
        dw = _grad_reduce_data(dwl, rules, dim=1)
        return dx, dw.astype(cd)

    dx, dw = bwd(xq, sx, w, g)
    return dx, dw


def _tp_row_fwd_mx(x, w, policy, rules):
    """Row-parallel forward, MX wire: the contraction axis (features) is
    model-sharded, so each shard quantizes per-(row × group) along its
    local N slice — group boundaries coincide with shard boundaries
    (the ``tp_applicable``/``proj`` divisibility gates) — contracts
    locally in f32, and the partial products ship over the MX a2a wire
    (fp8 payload + packed E8M0 byte grid, groups along K)."""
    ba, axis, tp = _axes(rules)
    mxf = get_mx_format(policy.mx_fwd)
    g = mxf.group
    if (x.shape[-1] // tp) % g or w.shape[1] % g:
        # fwd groups run along the local feature slice, dgrad groups
        # along the full output dim K; tp_applicable can't see w, so
        # direct callers fail fast here (proj() routes such shapes to
        # the GSPMD fallback instead)
        raise ValueError(
            f"MX TP row GEMM needs N/tp and K divisible by the group: "
            f"N={x.shape[-1]}, K={w.shape[1]}, tp={tp}, group={g}")
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, None, axis), P(axis, rules.fsdp_axis)),
        out_specs=(P(ba, axis, None), P(ba, None, axis), P(ba, None, axis)),
        axis_names=manual, check_vma=False)
    def fwd(xl, wl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=1, tiled=True)
        xq, sx8 = _quant_mx(xl, mxf)                  # groups along Nl
        wq, sw8 = _quant_mx(wg.T, mxf)                # [K, Nl], along Nl
        part = jnp.einsum("bsn,kn->bsk",
                          _deq_mx(xq, sx8, mxf), _deq_mx(wq, sw8, mxf),
                          preferred_element_type=jnp.float32)
        y = _a2a_sum(part, axis, tp, 1, mx=mxf)
        return y.astype(cd), xq, sx8

    y, xq, sx8 = fwd(x, w)
    return y, (xq, sx8, w)


def _tp_row_bwd_mx(policy, rules, res, g_ct):
    """dgrad: the local cotangent quantizes per-group along K and the
    payload + byte grid gather over the model axis (full tokens); each
    shard contracts the full K for its own N columns.  wgrad: both
    operands re-group along the contracted token axis — x from its
    fwd-quantized payload (one wire rounding), g from the gathered
    wire payload (same one rounding the per-tensor path takes) — and
    the ZeRO data reduction ships narrow payloads + E8M0 bytes, falling
    back to bf16 only if the FSDP split breaks group alignment."""
    ba, axis, tp = _axes(rules)
    mxf = get_mx_format(policy.mx_fwd)
    mxb = get_mx_format(policy.mx_bwd_name)
    mxwa = get_mx_format(policy.mx_wgrad_act_name)
    mxwg = get_mx_format(policy.mx_wgrad_grad_name)
    g = mxf.group
    xq, sx8, w = res
    cd = policy.compute_dtype
    manual = set(ba) | {axis, rules.fsdp_axis}

    @functools.partial(
        shard_map, mesh=rules.mesh,
        in_specs=(P(ba, None, axis), P(ba, None, axis),
                  P(axis, rules.fsdp_axis), P(ba, axis, None)),
        out_specs=(P(ba, None, axis), P(axis, rules.fsdp_axis)),
        axis_names=manual, check_vma=False)
    def bwd(xql, sx8l, wl, gl):
        wg = jax.lax.all_gather(wl, rules.fsdp_axis, axis=1, tiled=True)
        gq, sg8 = _quant_mx(gl, mxb)                  # [B, Sl, K], bwd fmt
        gg = jax.lax.all_gather(gq, axis, axis=1, tiled=True)   # narrow wire
        sgg8 = jax.lax.all_gather(sg8, axis, axis=1, tiled=True)  # bytes
        gf = _deq_mx(gg, sgg8, mxb)                   # [B, S, K] f32
        wqk, swk8 = _quant_mx(wg, mxf)                # w rows, along K
        dx = jnp.einsum("bsk,nk->bsn", gf, _deq_mx(wqk, swk8, mxf),
                        preferred_element_type=jnp.float32).astype(cd)
        # wgrad: re-group both operands along the contracted token axis
        # in the policy's wgrad formats
        xf = _deq_mx(xql, sx8l, mxf)                  # [B, S, Nl] f32
        xqt, sxt8 = _quant_mx(xf.transpose(0, 2, 1), mxwa)  # [B, Nl, S]
        gqt, sgt8 = _quant_mx(gf.transpose(0, 2, 1), mxwg)  # [B, K, S]
        dwl = jnp.einsum("bns,bks->nk",
                         _deq_mx(xqt, sxt8, mxwa), _deq_mx(gqt, sgt8, mxwg),
                         preferred_element_type=jnp.float32)
        # ZeRO reduce over data lands on dim1 (w is [N_model, K_fsdp])
        dw = _grad_reduce_data(dwl, rules, dim=1, mx=mxwg)
        return dx, dw.astype(cd)

    dx, dw = bwd(xq, sx8, w, g_ct)
    return dx, dw


tp_row_linear.defvjp(_tp_row_fwd, _tp_row_bwd)


# ------------------------------------------------------------- embedding --

def embed_ep_applicable(tokens, table, rules) -> bool:
    if rules is None or rules.mesh is None or not rules.seq_shard:
        return False
    tp = rules.model_size
    dp = 1
    for a in rules.batch_axes:
        dp *= rules.mesh.shape[a]
    return (tp > 1 and tokens.ndim == 2 and table.shape[0] % tp == 0
            and tokens.shape[1] % tp == 0 and tokens.shape[0] % dp == 0
            and table.shape[1] % rules.mesh.shape[rules.fsdp_axis] == 0)


def embed_lookup_ep(table, tokens, rules):
    """Vocab-parallel embedding lookup (§Perf G3).

    GSPMD lowers ``table[tokens]`` on a vocab-sharded table by REPLICATING
    the table ("involuntary full rematerialization"). Here each model
    shard looks up only its vocab slice (zeros elsewhere) and the partial
    rows are summed via the narrow-wire a2a, landing directly in the
    sequence-parallel layout the first block wants.
    """
    mesh, axis, tp = rules.mesh, rules.model_axis, rules.model_size
    ba = rules.batch_axes
    manual = set(ba) | {axis, rules.fsdp_axis}
    gather_d = make_fsdp_gather(rules, dim=1)
    vloc = table.shape[0] // tp

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis, rules.fsdp_axis), P(ba, None)),
        out_specs=P(ba, axis, None),
        axis_names=manual, check_vma=False)
    def f(tbl_l, tok_l):
        tbl = gather_d(tbl_l)                       # [V/tp, D] bf16
        off = jax.lax.axis_index(axis) * vloc
        idx = tok_l - off
        ok = (idx >= 0) & (idx < vloc)
        vals = jnp.where(ok[..., None],
                         tbl[jnp.clip(idx, 0, vloc - 1)], 0)
        y = _a2a_sum(vals.astype(jnp.float32), axis, tp, 1)
        return y.astype(tbl_l.dtype)

    return f(table, tokens)
