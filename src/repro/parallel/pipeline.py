"""GPipe-style pipeline parallelism on a ``stage`` mesh axis.

The decoder stack is split into S stages (stage s holds layers
[s*L/S, (s+1)*L/S)); microbatches stream through with ``ppermute``
hand-offs. The schedule is the classic GPipe fill/steady/drain: M
microbatches complete in M + S - 1 ticks, bubble fraction
(S-1)/(M+S-1).

This is the optional third parallelism dimension for >2-pod scale-out
(DESIGN.md §4): 'pod' can be repurposed as the stage axis, making the
cross-pod hop a once-per-microbatch point-to-point transfer
(collective-permute) instead of a per-step all-reduce — the right trade
when DCN bandwidth, not ICI, is the binding constraint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from ..compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["gpipe"]


def gpipe(layer_fn, stage_params, x_micro, *, mesh: Mesh,
          axis: str = "stage"):
    """Run ``layer_fn`` as an S-stage pipeline.

    layer_fn(params_one_stage, x[mb, ...]) -> y[mb, ...]
    stage_params: pytree with leading dim S on every leaf (sharded over
        ``axis``); stage s applies its own slice.
    x_micro: [M, mb, ...] microbatched input (replicated).
    Returns [M, mb, ...] pipeline output (from the last stage).
    """
    s_count = mesh.shape[axis]
    m_count = x_micro.shape[0]

    def inner(params, xs):
        idx = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params)   # local stage's params
        buf = jnp.zeros_like(xs[0])                # handoff register
        outs = jnp.zeros_like(xs)
        perm = [(i, i + 1) for i in range(s_count - 1)]
        for t in range(m_count + s_count - 1):
            feed = xs[t] if t < m_count else jnp.zeros_like(xs[0])
            inp = jnp.where(idx == 0, feed, buf)
            y = layer_fn(p, inp)
            buf = jax.lax.ppermute(y, axis, perm)
            k = t - (s_count - 1)
            if 0 <= k < m_count:
                outs = outs.at[k].set(y)           # valid on last stage
        return outs[None]                          # [1, M, mb, ...] local

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    out = shard_map(
        inner, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(axis),
        check_vma=False)(stage_params, x_micro)
    return out[-1]                                  # last stage's outputs
