"""Sharding rules: logical-axis mapping for activations and parameters.

Mesh axes (launch/mesh.py):
  * ``pod``   — optional outer data-parallel axis across pods (ICI/DCN)
  * ``data``  — data parallel + ZeRO/FSDP parameter sharding
  * ``model`` — tensor/expert parallel (Megatron-style)

Parameter rules are matched by path substring, most-specific first. Every
2D+ parameter is additionally FSDP-sharded along its non-TP dimension over
``data`` so that optimizer state is fully partitioned (ZeRO-3); gradients
then reduce-scatter instead of all-reduce automatically under GSPMD.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshRules", "make_rules", "param_pspecs", "batch_pspec"]


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Optional[Mesh]
    batch_axes: tuple  # axes a batch dim shards over, e.g. ('pod', 'data')
    fsdp_axis: Optional[str] = "data"
    model_axis: Optional[str] = "model"
    #: shard sequence dim over the model axis (sequence parallelism) —
    #: used for long-context cells where batch can't be sharded.
    seq_shard: bool = False

    def _axis(self, logical):
        return {
            "batch": self.batch_axes,
            "embed": None,
            "seq": self.model_axis if self.seq_shard else None,
            "heads": self.model_axis,
            "kv_heads": self.model_axis,
            "ff": self.model_axis,
            "vocab": self.model_axis,
            "experts": self.model_axis,
            None: None,
        }[logical]

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis not in self.mesh.axis_names:
            return 1
        return self.mesh.shape[self.model_axis]

    def act(self, x, *logical):
        """Constrain activation ``x`` whose dims carry the logical names.
        Dims not divisible by their target axis are left unconstrained
        (GSPMD would otherwise pad + full-remat on transitions)."""
        if self.mesh is None:
            return x
        axes = []
        for i, n in enumerate(logical):
            a = self._axis(n)
            if a is not None:
                size = 1
                for ax in ((a,) if isinstance(a, str) else a):
                    size *= self.mesh.shape[ax]
                if x.shape[i] % size:
                    a = None
            axes.append(a)
        spec = P(*axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def logits(self, x):
        return self.act(x, "batch", None, "vocab")

    def gather_seq(self, x):
        """Megatron-SP g-bar: all-gather the seq dim on the forward pass,
        reduce-scatter the cotangent on the backward pass. A plain
        with_sharding_constraint would constrain the cotangent to the
        *forward* (unsharded) spec, forcing a 2x-wire all-reduce of every
        dgrad partial sum (§Perf D3)."""
        if self.mesh is None or not self.seq_shard:
            return x
        return _gather_seq_cv(x, self)


def _gather_seq_cv(x, rules: "MeshRules"):
    def fwd_c(v):
        # the barrier pins the gather to THIS (bf16) tensor — without it
        # GSPMD hoists the gather into the f32 interior of the fused
        # norm/quantize chain, doubling wire bytes (§Perf D4)
        v = jax.lax.optimization_barrier(v)
        return rules.act(v, "batch", None, None)

    def bwd_c(v):
        v = jax.lax.optimization_barrier(v)
        return rules.act(v, "batch", "seq", None)

    @jax.custom_vjp
    def g(v):
        return fwd_c(v)

    def g_fwd(v):
        return fwd_c(v), None

    def g_bwd(_, ct):
        return (bwd_c(ct),)

    g.defvjp(g_fwd, g_bwd)
    return g(x)


def make_rules(mesh: Optional[Mesh], *, seq_shard: bool = False) -> MeshRules:
    if mesh is None:
        return MeshRules(None, ("data",))
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fsdp = "data" if "data" in mesh.axis_names else None
    model = "model" if "model" in mesh.axis_names else None
    return MeshRules(mesh, batch_axes, fsdp, model, seq_shard)


# --------------------------------------------------------------------------
# Parameter partition rules. Path is the '/'-joined tree path. ``L`` marks
# the stacked-layer leading dim (never sharded). F = fsdp ('data'),
# M = model. Order matters: first match wins.
# --------------------------------------------------------------------------
_PARAM_RULES: Sequence[tuple[str, tuple]] = (
    # MoE expert weights [L, E, D, F] / [L, E, F, D]: experts over model,
    # FSDP over the dim-2.
    (r"experts.*(w_in|w_gate|w_up)", ("L", "M", "F", None)),
    (r"experts.*w_out", ("L", "M", None, "F")),
    (r"router", ("L", "F", None)),
    # attention projections [L, D, H*hd] (col-parallel) / [L, H*hd, D] (row)
    (r"(wq|wk|wv|in_proj|qkv)", ("L", "F", "M")),
    (r"(wo|out_proj)", ("L", "M", "F")),
    # MLP [L, D, F] col-parallel, [L, F, D] row-parallel
    (r"(w_gate|w_up|w_in)", ("L", "F", "M")),
    (r"(w_down|w_out)", ("L", "M", "F")),
    # embeddings [V, D]: vocab over model (Megatron vocab-parallel), D fsdp
    (r"(embed|lm_head|patch_proj|frame_proj)", ("M", "F")),
    # mamba/xlstm extras: conv kernels, gates, per-head params — replicate
    # except large 2D which fall through to the generic rule below.
)


def _spec_for(path: str, shape, stacked: bool, axis_sizes) -> P:
    ndim = len(shape)

    def fit(axis, dim):
        """Drop shardings that don't divide the dim (jit in_shardings
        require exact divisibility, unlike internal constraints)."""
        if axis is None:
            return None
        size = axis_sizes.get(axis, 1)
        return axis if (size > 1 and dim % size == 0 and dim >= size) else None

    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            # strip the 'L' slot and right-align the remaining logical dims
            # onto the trailing axes — models may stack params under any
            # number of leading scan dims (layers, groups x per-group, ...)
            log = [a for a in logical if a != "L"]
            log = log[-ndim:] if len(log) > ndim else log
            axes = [None] * (ndim - len(log)) + [
                "data" if a == "F" else "model" if a == "M" else None
                for a in log]
            axes = [fit(a, shape[i]) for i, a in enumerate(axes)]
            return P(*axes)
    # generic fallback: FSDP-shard the largest dim of big tensors
    if ndim >= 2 and max(shape) >= 1024:
        axes = [None] * ndim
        i = int(max(range(ndim), key=lambda j: shape[j]))
        axes[i] = fit("data", shape[i])
        return P(*axes)
    return P()


def param_pspecs(params_shapes, mesh=None) -> object:
    """Build a PartitionSpec tree matching a params(-shape) tree.

    Rules are right-aligned onto trailing dims, so any number of leading
    scan-stack dims (layers / groups x per-group) is handled uniformly.
    """
    axis_sizes = dict(mesh.shape) if mesh is not None else {
        "data": 1, "model": 1}

    def to_spec(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        return _spec_for(pstr, leaf.shape, stacked=False,
                         axis_sizes=axis_sizes)

    return jax.tree_util.tree_map_with_path(to_spec, params_shapes)


def batch_pspec(rules: MeshRules) -> P:
    return P(rules.batch_axes)
