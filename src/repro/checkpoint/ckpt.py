"""Checkpointing: atomic, async, sharded, resumable.

Layout (one directory per step):

    ckpt_dir/step_000123/
        manifest.json        tree structure, dtypes, shapes, step
        host0000.npz         this host's leaf shards (flattened keys)
    ckpt_dir/LATEST          -> "step_000123" (atomic rename)

* **atomic**: writes go to ``step_X.tmp`` then ``os.replace`` — a crash
  mid-save never corrupts the restorable state (fault tolerance).
* **async**: ``save_async`` snapshots to host RAM (device_get) and writes
  on a background thread so the step loop isn't blocked.
* **resharding restore**: leaves are saved unsharded per-host (single-host
  container) or per-shard with index metadata; restore accepts any device
  layout — the loader re-shards via jax.device_put, so a 256-chip
  checkpoint restores onto 512 chips (elastic scaling).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf
    return out, jax.tree_util.tree_structure(tree)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree: Any):
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._write(step, host)

    def save_async(self, step: int, tree: Any):
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(target=self._write,
                                        args=(step, host), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        flat, _ = _flatten(host_tree)
        # store raw bytes: npz can't round-trip ml_dtypes (bf16/fp8)
        np.savez(os.path.join(tmp, "host0000.npz"),
                 **{k: np.ascontiguousarray(v).view(np.uint8)
                    for k, v in flat.items()})
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(np.shape(v)),
                         "dtype": str(np.asarray(v).dtype)}
                     for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            import shutil
            shutil.rmtree(final)
        os.replace(tmp, final)                       # atomic publish
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(name)
        os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.dir, "LATEST")
        if not os.path.exists(latest):
            return None
        with open(latest) as f:
            return int(f.read().strip().split("_")[1])

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; optionally re-shard
        (jax.device_put with NamedSharding tree) — elastic-safe."""
        name = f"step_{step:08d}"
        data = np.load(os.path.join(self.dir, name, "host0000.npz"))
        flat_like, treedef = _flatten(like)
        leaves = []
        for key, ref in flat_like.items():
            refdtype = np.dtype(ref.dtype)
            shape = tuple(np.shape(ref))
            arr = data[key].view(refdtype).reshape(shape)
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree
