"""build_model — family dispatch."""
from __future__ import annotations

from ..configs.base import ModelConfig
from . import transformer as T

__all__ = ["build_model"]


def build_model(cfg: ModelConfig) -> T.ModelApi:
    if cfg.family in ("dense", "moe", "vlm"):
        return T.build_dense(cfg)
    if cfg.family == "encdec":
        return T.build_encdec(cfg)
    if cfg.family == "xlstm":
        return T.build_xlstm(cfg)
    if cfg.family == "hybrid":
        return T.build_hybrid(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
