"""Mixture-of-Experts FFN: sort-based token grouping (MaxText/MegaBlocks
style), expert-parallel over the ``model`` mesh axis.

Dispatch avoids the O(T*E*C) one-hot tensors: top-k expert ids are sorted,
tokens are scattered into a capacity-bounded [E, C, D] buffer (dropping
overflow — standard capacity-factor semantics), each expert runs a dense
(quantized, expanding-GEMM) FFN over its buffer, and results are gathered
back weighted by router probabilities. GSPMD turns the data->expert
resharding into all-to-alls on the ``model`` axis.

Arctic's "dense residual" (a parallel always-on FFN) is supported via
``cfg.moe_dense_ff``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import functools

from ..compat import shard_map
from jax.sharding import PartitionSpec as P

from ..core.formats import get_mx_format
from ..core.linear import linear
from . import layers

__all__ = ["init_moe", "moe_ffn"]


def _ep_capacity(cfg, t_loc: int, e_pad: int) -> int:
    """Per-expert buffer capacity on the EP path.  Clamped to the local
    token supply (``t_loc * k`` routes exist in total — a capacity above
    that only allocates dispatch buffer that can never fill, which for
    large ``capacity_factor`` made the a2a buffers *bigger* than the
    token stream they carry)."""
    c = int(cfg.top_k * t_loc * cfg.capacity_factor / e_pad)
    return max(8, min(c, t_loc * cfg.top_k))


def _aux_metrics(loss, keep, cap, axis=None, ba=()):
    """The aux dict both MoE paths return: the router load-balancing
    ``loss`` (what the trainer adds to CE), the realized ``drop_frac``
    (fraction of (token, k) routes beyond capacity — the observable the
    capacity clamp trades against), and the ``capacity`` itself."""
    drop = 1.0 - jnp.mean(keep.astype(jnp.float32))
    if axis is not None:
        drop = jax.lax.pmean(jax.lax.pmean(drop, axis), ba)
    return {"loss": loss, "drop_frac": drop,
            "capacity": jnp.float32(cap)}


def _ep_applicable(x, cfg, rules):
    if rules is None or rules.mesh is None or rules.model_size <= 1:
        return False
    dp = 1
    for a in rules.batch_axes:
        dp *= rules.mesh.shape[a]
    if not (x.ndim == 3 and x.shape[0] % dp == 0 and dp > 0):
        return False
    # capacity padding dominates when local tokens << experts (decode with
    # tiny per-shard batches) — the local einsum dispatch is cheaper there
    tp = rules.model_size
    e_pad = -(-cfg.n_experts // tp) * tp
    t_loc = (x.shape[0] // dp) * x.shape[1]
    return t_loc * cfg.top_k >= e_pad


def moe_ffn_ep(x, p, cfg, policy, *, rules, impl="auto"):
    """Expert-parallel MoE via fully-manual shard_map (§Perf G1).

    Tokens are batch-sharded; experts are sharded over the ``model`` axis
    (padded to a multiple of it). Each shard routes its own tokens, sorts
    them by expert, ships capacity-bounded buffers — packed MX payloads +
    E8M0 group grids under MX policies (DESIGN.md §13), carrier bf16
    otherwise — with ONE all-to-all, runs its local experts, and ships
    results back with a second all-to-all. No GSPMD resharding of the dispatch tensors can
    occur — this replaces the O(10 TB) gather/AR storm the einsum dispatch
    generates at 256 chips.
    """
    mesh, axis, tp = rules.mesh, rules.model_axis, rules.model_size
    ba = rules.batch_axes
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    e_pad = -(-e // tp) * tp
    epl = e_pad // tp
    manual = set(ba) | {axis}

    # pad expert weights/router on the expert dim (outside the manual region)
    wg = jnp.pad(p["experts"]["w_gate"], ((0, e_pad - e), (0, 0), (0, 0)))
    wu = jnp.pad(p["experts"]["w_up"], ((0, e_pad - e), (0, 0), (0, 0)))
    wo = jnp.pad(p["experts"]["w_out"], ((0, e_pad - e), (0, 0), (0, 0)))
    router = jnp.pad(p["router"].astype(jnp.float32),
                     ((0, 0), (0, e_pad - e)))  # logits masked inside

    dp = 1
    for a in ba:
        dp *= mesh.shape[a]
    t_loc = (b // dp) * s
    cap = _ep_capacity(cfg, t_loc, e_pad)
    manual = manual | {rules.fsdp_axis}
    from ..parallel.tp_gemm import make_fsdp_gather, mx_dispatch_a2a
    # packed dispatch wire (DESIGN.md §13): MX policies ship both
    # dispatch a2as as codec payloads + E8M0 grids over groups of 32
    # along d_model — activations in the forward element format, the
    # dispatch cotangent in the backward one.  Misaligned d_model keeps
    # the raw carrier a2a (the grid would cut a group).
    mx_fwd = get_mx_format(policy.mx_fwd) if policy.mx else None
    mx_bwd = get_mx_format(policy.mx_bwd_name) if policy.mx else None
    use_mx_wire = mx_fwd is not None and d % mx_fwd.group == 0

    def dispatch_a2a(buf):
        if use_mx_wire:
            return mx_dispatch_a2a(buf, axis, mx_fwd, mx_bwd)
        return jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
    # w_gate/w_up are [E, D(fsdp), F]; w_out is [E, F, D(fsdp)]
    fsdp_gather1 = make_fsdp_gather(rules, dim=1)
    fsdp_gather2 = make_fsdp_gather(rules, dim=2)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(ba, None, None), P(None, None),
                  P(axis, rules.fsdp_axis, None),
                  P(axis, rules.fsdp_axis, None),
                  P(axis, None, rules.fsdp_axis)),
        out_specs=(P(ba, None, None), P()),
        axis_names=manual, check_vma=False)
    def ep(xl, rtr, wgl, wul, wol):
        # ZeRO-3 weight gather inside the manual region: no boundary
        # resharding, narrow-wire gradient RS on the way back (§Perf G2)
        wgl = fsdp_gather1(wgl)
        wul = fsdp_gather1(wul)
        wol = fsdp_gather2(wol)
        bl = xl.shape[0]
        xt = xl.reshape(bl * s, d)
        t = bl * s
        logits = jnp.dot(xt.astype(jnp.float32), rtr)
        # mask the padded expert columns (never routable)
        eidx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(eidx < e, logits, -1e9)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eid = jax.lax.top_k(probs, k)
        gate = gate / jnp.sum(gate, -1, keepdims=True)

        me = jnp.mean(probs[:, :e], axis=0)
        ce = jnp.mean(jax.nn.one_hot(eid[:, 0], e, dtype=jnp.float32), 0)
        aux = cfg.router_aux_coef * e * jnp.sum(me * ce)
        aux = jax.lax.pmean(jax.lax.pmean(aux, axis), ba)

        # local sort-based dispatch into [e_pad * cap, d]
        flat_e = eid.reshape(-1)
        order = jnp.argsort(flat_e)
        tok_of = order // k
        se = flat_e[order]
        pos = jnp.arange(t * k)
        seg = jnp.searchsorted(se, jnp.arange(e_pad), side="left")
        rank = pos - seg[se]
        keep = rank < cap
        slot = jnp.where(keep, se * cap + rank, e_pad * cap)
        send = jnp.zeros((e_pad * cap + 1, d), xl.dtype
                         ).at[slot].set(xt[tok_of])[:-1]
        # ship to expert shards: [tp, epl*cap, d] -> a2a -> local experts
        send = send.reshape(tp, epl * cap, d)
        recv = dispatch_a2a(send)
        buf = recv.reshape(tp, epl, cap, d).transpose(1, 0, 2, 3) \
                  .reshape(epl, tp * cap, d)

        def expert(xb, g_, u_, o_):
            gg = linear(xb, g_, policy=policy, impl=impl)
            uu = linear(xb, u_, policy=policy, impl=impl)
            hh = jax.nn.silu(gg.astype(jnp.float32)).astype(gg.dtype) * uu
            return linear(hh, o_, policy=policy, impl=impl)

        out = jax.vmap(expert)(buf, wgl, wul, wol)
        out = out.reshape(epl, tp, cap, d).transpose(1, 0, 2, 3) \
                 .reshape(tp, epl * cap, d)
        back = dispatch_a2a(out)
        flat_out = back.reshape(e_pad * cap, d)
        gathered = jnp.where(keep[:, None],
                             flat_out[jnp.where(keep, slot, 0)], 0)
        contrib = gathered * gate.reshape(-1)[order][:, None].astype(xl.dtype)
        yt = jnp.zeros((t, d), jnp.float32).at[tok_of].add(
            contrib.astype(jnp.float32))
        return (yt.astype(xl.dtype).reshape(bl, s, d),
                _aux_metrics(aux, keep, cap, axis=axis, ba=ba))

    y, aux = ep(x, router, wg, wu, wo)
    if cfg.moe_dense_ff:
        y = y + layers.mlp(x, p["dense"], cfg, policy, rules=rules,
                           impl=impl)
    return y, aux


def init_moe(key, cfg, dtype):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s,
        "experts": {
            "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * s,
            "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * s,
            "w_out": jax.random.normal(ks[3], (e, f, d), dtype) * (f ** -0.5),
        },
    }
    if cfg.moe_dense_ff:
        p["dense"] = layers.init_mlp(ks[4], cfg, dtype, d_ff=cfg.moe_dense_ff)
    return p


def _capacity(cfg, n_tokens: int) -> int:
    c = int(cfg.top_k * n_tokens * cfg.capacity_factor / cfg.n_experts)
    return max(8, min(c, n_tokens))


def moe_ffn(x, p, cfg, policy, *, rules=None, impl="auto"):
    """x [B,S,D] -> ([B,S,D], aux) where ``aux`` is the metrics dict of
    ``_aux_metrics`` (``aux["loss"]`` is what joins the objective).
    Dispatches to the explicit expert-parallel path on multi-device
    meshes (§Perf G1); the einsum path below is the single-device /
    reference implementation."""
    if _ep_applicable(x, cfg, rules):
        return moe_ffn_ep(x, p, cfg, policy, rules=rules, impl=impl)
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    # --- router (fp32: small and accuracy-critical; never quantized) ---
    logits = jnp.dot(xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # [T,E]
    gate, eid = jax.lax.top_k(probs, k)                         # [T,k]
    gate = gate / jnp.sum(gate, -1, keepdims=True)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(eid[:, 0], e, dtype=jnp.float32), axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    # --- sort-based dispatch into [E, C, D] ---
    cap = _capacity(cfg, t)
    flat_e = eid.reshape(-1)                                    # [T*k]
    order = jnp.argsort(flat_e)                                 # stable
    tok_of = order // k                                         # token index
    se = flat_e[order]
    # rank within expert segment
    pos = jnp.arange(t * k)
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")  # [E]
    rank = pos - seg_start[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)            # overflow bin
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(xt[tok_of])
    buf = buf[:-1].reshape(e, cap, d)
    if rules is not None:
        buf = rules.act(buf, "experts", None, None)

    # --- expert FFN (batched over experts; quantized expanding GEMMs) ---
    def expert_mlp(xb, wg, wu, wo):
        g = linear(xb, wg, policy=policy, impl=impl)
        u = linear(xb, wu, policy=policy, impl=impl)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype) * u
        return linear(h, wo, policy=policy, impl=impl)

    out_buf = jax.vmap(expert_mlp)(buf, p["experts"]["w_gate"],
                                   p["experts"]["w_up"], p["experts"]["w_out"])
    if rules is not None:
        out_buf = rules.act(out_buf, "experts", None, None)

    # --- gather back + combine with gate weights ---
    flat_out = out_buf.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], flat_out[jnp.where(keep, slot, 0)], 0)
    contrib = gathered * gate.reshape(-1)[order][:, None].astype(x.dtype)
    yt = jnp.zeros((t, d), jnp.float32).at[tok_of].add(
        contrib.astype(jnp.float32))
    y = yt.astype(x.dtype).reshape(b, s, d)

    if cfg.moe_dense_ff:
        y = y + layers.mlp(x, p["dense"], cfg, policy, rules=rules, impl=impl)
    return y, _aux_metrics(aux, keep, cap)
