"""Mamba2 (SSD) block — used standalone and inside the zamba2 hybrid.

Structure per block: in_proj -> (z, x, B, C, dt); short causal depthwise
conv over (x, B, C); selective state-space recurrence via the shared
chunked-GLA engine; gated RMSNorm; out_proj. Projections are quantized
(expanding GEMM); the recurrent state accumulates in f32 — the paper's
"accumulate wide" rule applied to the SSM state (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.linear import linear
from .layers import rms_norm
from .ssm import chunked_gla, gla_step

__all__ = ["init_mamba2", "mamba2_block", "init_mamba2_cache"]


def _conv_channels(cfg):
    return cfg.ssm_inner + 2 * cfg.ssm_state


def init_mamba2(key, cfg, dtype):
    d, di, n, h = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        # order: z | x | B | C | dt
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * di + 2 * n + h), dtype) * s,
        "conv_w": jax.random.normal(
            ks[1], (cfg.ssm_conv, _conv_channels(cfg)), dtype) * 0.2,
        "conv_b": jnp.zeros((_conv_channels(cfg),), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),           # A = -exp(a_log)
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),    # softplus ~ 0.12
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[2], (di, d), dtype) * (di ** -0.5),
    }


def _causal_depthwise_conv(u, w, b):
    """u [B,S,C]; w [K,C] depthwise causal conv (K small, unrolled taps)."""
    k = w.shape[0]
    uf = u.astype(jnp.float32)
    s = uf.shape[1]
    out = sum(
        jnp.pad(uf, ((0, 0), (k - 1 - i, 0), (0, 0)))[:, :s, :]
        * w[i].astype(jnp.float32)
        for i in range(k))
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(u.dtype)


def _split_proj(proj, cfg):
    di, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def mamba2_block(x, p, cfg, policy, *, cache=None, rules=None, impl="auto"):
    """x [B,S,D] -> ([B,S,D], new_cache). cache = {'h', 'conv'} for decode."""
    b, s, d = x.shape
    di, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_headdim

    proj = linear(x, p["in_proj"], policy=policy, impl=impl)
    z, xbc, dt_raw = _split_proj(proj, cfg)

    new_cache = None
    if cache is None:
        raw_tail = xbc.astype(jnp.float32)[:, -(p["conv_w"].shape[0] - 1):, :]
        new_conv = jnp.pad(
            raw_tail,
            ((0, 0), (max(0, p["conv_w"].shape[0] - 1 - s), 0), (0, 0)))
        xbc = _causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"])
    else:
        # decode: roll the conv window state [B, K-1, C]
        window = jnp.concatenate([cache["conv"], xbc.astype(jnp.float32)], 1)
        k = p["conv_w"].shape[0]
        out = jnp.einsum("bkc,kc->bc", window[:, -k:, :],
                         p["conv_w"].astype(jnp.float32))
        xbc = jax.nn.silu(out + p["conv_b"].astype(jnp.float32))[:, None, :]
        xbc = xbc.astype(x.dtype)
        new_conv = window[:, -(k - 1):, :]

    xin = xbc[..., :di].reshape(b, s, h, pdim)
    bmat = xbc[..., di:di + n]
    cmat = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                      # [B,S,H]
    a = -jnp.exp(p["a_log"])                                  # [H] < 0
    log_decay = dt * a                                        # [B,S,H]

    # GLA mapping: khat = dt*B (per head), vhat = x, qhat = C
    khat = dt[..., None] * bmat[:, :, None, :]                # [B,S,H,N]
    qhat = jnp.broadcast_to(cmat[:, :, None, :], (b, s, h, n))
    if cache is None:
        y, hT = chunked_gla(qhat, khat, xin, log_decay, None, chunk=128)
    else:
        y, hT = gla_step(qhat[:, 0], khat[:, 0], xin[:, 0],
                         log_decay[:, 0], cache["h"])
        y = y[:, None]
    new_cache = {"h": hT, "conv": new_conv}

    y = y + cfg_skip(p, xin)
    y = y.reshape(b, s, di).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm_scale"], cfg.norm_eps)
    out = linear(y, p["out_proj"], policy=policy, impl=impl)
    return out, new_cache


def cfg_skip(p, xin):
    return (p["d_skip"][None, None, :, None] * xin.astype(jnp.float32))


def init_mamba2_cache(cfg, batch):
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                        cfg.ssm_headdim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, _conv_channels(cfg)),
                          jnp.float32),
    }
