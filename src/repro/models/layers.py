"""Shared building blocks: norms, RoPE, GQA attention (train/prefill/decode),
MLPs. Every projection routes through ``core.linear`` so the paper's
quantized expanding GEMM is the universal compute primitive.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.linear import linear
from ..core.policy import get_policy
from ..kernels import ops, ref
from ..parallel.tp_gemm import (tp_applicable, tp_column_linear,
                                tp_row_linear)


def proj(x, w, b, policy, rules, impl, kind="plain", quantized=True):
    """Projection router: explicit narrow-wire TP GEMMs when applicable
    (train/prefill with sequence parallelism), GSPMD qlinear otherwise.

    Block-scaled policies (``policy.block_scale > 0``) ride the same TP
    path: operands quantize per-(row-tile × K-tile) block and the fp8
    payloads ship with their scale grids riding along, so ``hfp8_block``
    composes with sequence parallelism instead of falling back to a
    GSPMD reshard (DESIGN.md §3, "block scaling × TP/SP").

    MX policies (``mxfp8``/``mxfp6``/``mxfp4`` — DESIGN.md §9/§10) ride
    the same wire natively: operands quantize per-(row × group-of-32)
    and the narrow payloads — native fp8 bytes, or *packed* sub-byte
    codec lanes (FP6: 0.75 B/elem, FP4: 0.5 B/elem) — ship with packed
    E8M0 byte grids riding along (one uint8 per group, ~1/32 of payload
    bytes), provided every contraction axis the groups run along — K
    forward, the local N columns for dgrad, the token axis for wgrad —
    tiles into whole groups (group alignment subsumes pack alignment);
    otherwise they fall back to the GSPMD-sharded packed MX pipeline
    (``ops.mx_gemm_packed``), which is numerically identical either
    way."""
    ok = quantized and tp_applicable(x, rules, policy)
    if ok:
        tp = rules.model_size
        dp = 1
        for a in rules.batch_axes:
            dp *= rules.mesh.shape[a]
        if kind == "col":
            ok = w.shape[0] % dp == 0 and w.shape[1] % tp == 0
        elif kind == "row":
            ok = (w.shape[0] % tp == 0 and w.shape[1] % dp == 0
                  and x.shape[2] % tp == 0)
        else:
            ok = False
    if ok and getattr(policy, "mx_fwd", ""):
        # group structure must survive the model-axis split: dgrad
        # groups run along the local N columns (col) / the local
        # feature slice (row)
        from ..core.formats import get_mx_format
        g = get_mx_format(policy.mx_fwd).group
        if kind == "col":
            ok = w.shape[0] % g == 0 and (w.shape[1] // tp) % g == 0
        else:
            # row: fwd groups along the local feature slice, dgrad
            # groups along the full output dim K = w.shape[1]
            ok = (x.shape[2] // tp) % g == 0 and w.shape[1] % g == 0
    if ok and kind == "col":
        y = tp_column_linear(x, w, policy, rules)
    elif ok and kind == "row":
        y = tp_row_linear(x, w, policy, rules)
    else:
        return linear(x, w, b, policy=policy, impl=impl, quantized=quantized)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y

# ---------------------------------------------------------------------------
# Norms (computed in f32, cast back — low-precision training hygiene)
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, p, cfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def init_norm(cfg, dtype):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def _rope_sincos(positions, head_dim, theta):
    """positions [..., S] -> sin/cos [..., S, head_dim//2] (f32)."""
    freqs = jnp.exp(
        -jnp.log(jnp.float32(theta))
        * (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, positions, theta):
    """x [..., S, H, hd]; positions [..., S]."""
    hd = x.shape[-1]
    sin, cos = _rope_sincos(positions, hd, theta)  # [..., S, hd/2]
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA): q-chunked exact softmax — O(chunk * T) score memory, so
# prefill_32k fits without a dedicated kernel; decode is a single-row case.
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype, d_model=None):
    d = d_model or cfg.d_model
    hd = cfg.head_dim_eff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, cfg.n_heads * hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, cfg.n_kv_heads * hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, cfg.n_kv_heads * hd), dtype) * s,
        "wo": jax.random.normal(k4, (cfg.n_heads * hd, d), dtype) * s,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def _sdpa_chunked(q, k, v, *, causal, q_positions, kv_valid_len, chunk,
                  rules=None):
    """q [B,S,H,hd], k/v [B,T,KV,hd] -> [B,S,H,hd]. Exact, chunked over S.

    ``q_positions`` [S] absolute positions for causal masking;
    ``kv_valid_len`` masks cache slots >= this length (decode).
    """
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    rep = h // kv
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    scale = hd ** -0.5
    tpos = jnp.arange(t)

    def one_chunk(qc, pc):
        # qc [B,C,H,hd]; scores [B,H,C,T]
        sc = jnp.einsum("bchd,bthd->bhct", qc.astype(jnp.float32),
                        kr.astype(jnp.float32)) * scale
        mask = tpos[None, :] <= pc[:, None] if causal else (
            jnp.ones((qc.shape[1], t), bool))
        if kv_valid_len is not None:
            mask = mask & (tpos[None, :] < kv_valid_len)
        sc = jnp.where(mask[None, None], sc, -jnp.inf)
        w = jax.nn.softmax(sc, axis=-1)
        # rows with no valid key (shouldn't happen) -> zeros, not NaN
        w = jnp.where(jnp.isnan(w), 0.0, w)
        return jnp.einsum("bhct,bthd->bchd", w, vr.astype(jnp.float32))

    if s <= chunk or s % chunk:
        out = one_chunk(q, q_positions)
    else:
        nc = s // chunk
        qs = q.reshape(b, nc, chunk, h, hd).swapaxes(0, 1)
        ps = q_positions.reshape(nc, chunk)
        out = jax.lax.map(lambda args: one_chunk(*args), (qs, ps))
        out = out.swapaxes(0, 1).reshape(b, s, h, hd)
    return out.astype(q.dtype)


# Quantized-KV attention under MX policies (DESIGN.md §11): forward
# runs the packed flash pipeline — k/v quantize per (row × group-of-32
# along hd) into packed payloads + E8M0 byte grids, the KV sweep
# decodes them in-register next to the f32 online-softmax accumulator.
# Backward recomputes exact-softmax attention on the *dequantized* KV
# (the packed payloads are the residuals — the same one-fwd-rounding
# memory story as qlinear's MX branch) and differentiates through it:
# straight-through across the quantization, exactly like the GEMM path.

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _mx_sdpa(q, k, v, mx_name: str, causal: bool, impl: str):
    """q/k/v [BH, S|T, hd] -> [BH, S, hd] with MX-quantized KV."""
    out, _ = _mx_sdpa_fwd(q, k, v, mx_name, causal, impl)
    return out


def _mx_sdpa_fwd(q, k, v, mx_name, causal, impl):
    kp, ks8 = ops.mx_quantize_kv(k, mx_name, impl=impl)
    vp, vs8 = ops.mx_quantize_kv(v, mx_name, impl=impl)
    out = ops.mx_flash_attention_packed(q, kp, ks8, vp, vs8, mx_k=mx_name,
                                        causal=causal, impl=impl)
    return out, (q, kp, ks8, vp, vs8)


def _mx_sdpa_bwd(mx_name, causal, impl, res, g):
    q, kp, ks8, vp, vs8 = res
    hd = q.shape[-1]
    kf = ops.mx_dequantize_packed(kp, ks8, mx_name, k=hd).astype(q.dtype)
    vf = ops.mx_dequantize_packed(vp, vs8, mx_name, k=hd).astype(q.dtype)
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention_ref(q_, k_, v_,
                                                   causal=causal),
        q, kf, vf)
    return vjp(g)


_mx_sdpa.defvjp(_mx_sdpa_fwd, _mx_sdpa_bwd)


def _mx_attention_applicable(policy, *, s, t, hd, kv_cache, cross_kv):
    """Route train/prefill self-attention through the quantized kernel?

    Requires an MX policy, no decode cache and no cross-KV (so q
    positions are 0..S-1 and the kernel's raw-index causal mask is the
    model's mask), hd a whole number of groups, and a legal S/T tiling.
    Anything else falls back to ``_sdpa_chunked`` — numerically the
    unquantized path, exactly as misaligned shapes fall off the TP wire.
    """
    if not getattr(policy, "mx", False) or not policy.mx_attn_name:
        return False
    if kv_cache is not None or cross_kv is not None:
        return False
    from ..core.formats import get_mx_format
    if hd % get_mx_format(policy.mx_attn_name).group != 0:
        return False
    return ops.attention_blocks(s, t) is not None


def attention(x, p, cfg, policy, *, positions, kv_cache=None, cross_kv=None,
              causal=None, rules=None, impl="auto"):
    """Returns (out [B,S,D], new_kv_cache).

    * train/prefill: kv_cache None -> full self-attention over x.
    * decode: kv_cache dict(k, v, idx) -> append and attend to the cache.
    * cross_kv (Bx[T,KV,hd] pair): encoder-decoder cross attention.
    """
    policy = get_policy(policy)
    b, s, _ = x.shape
    hd = cfg.head_dim_eff
    causal = cfg.causal if causal is None else causal

    q = proj(x, p["wq"], p.get("bq"), policy, rules, impl, kind="col")
    q = q.reshape(b, s, cfg.n_heads, hd)
    if cross_kv is None:
        k = proj(x, p["wk"], p.get("bk"), policy, rules, impl, kind="col")
        v = proj(x, p["wv"], p.get("bv"), policy, rules, impl, kind="col")
        k = k.reshape(b, s, cfg.n_kv_heads, hd)
        v = v.reshape(b, s, cfg.n_kv_heads, hd)
        if cfg.pos_embed == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = cross_kv
        causal = False

    if kv_cache is not None and "pt" in kv_cache:
        # paged serving cache (DESIGN.md §12): append the new rows into
        # the page pool (packed MX payloads or carrier pages) and run
        # the decode kernel against the gathered page slots.  RoPE was
        # applied above with per-sequence absolute positions [B, S].
        from ..serve.kv_cache import paged_attend
        out, new_kv = paged_attend(q, k, v, kv_cache["kv"], kv_cache["pt"],
                                   kv_cache["lens"], cfg=cfg, policy=policy,
                                   impl=impl)
        out = out.reshape(b, s, cfg.n_heads * hd)
        out = proj(out, p["wo"], None, policy, rules, impl, kind="row")
        return out, new_kv

    new_cache = None
    kv_valid_len = None
    if kv_cache is not None:
        idx = kv_cache["idx"]
        k = jax.lax.dynamic_update_slice(kv_cache["k"], k.astype(
            kv_cache["k"].dtype), (0, idx, 0, 0))
        v = jax.lax.dynamic_update_slice(kv_cache["v"], v.astype(
            kv_cache["v"].dtype), (0, idx, 0, 0))
        new_cache = {"k": k, "v": v, "idx": idx + s}
        kv_valid_len = idx + s
        # causal masking still applies via absolute positions (cache slots
        # are laid out absolutely); for decode s=1 it coincides with the
        # kv_valid_len mask.

    if rules is not None:
        q = rules.act(q, "batch", None, "heads", None)
        k = rules.act(k, "batch", None, "kv_heads" if cfg.n_kv_heads > 1 else None, None)
        v = rules.act(v, "batch", None, "kv_heads" if cfg.n_kv_heads > 1 else None, None)

    t = k.shape[1]
    if _mx_attention_applicable(policy, s=s, t=t, hd=hd, kv_cache=kv_cache,
                                cross_kv=cross_kv):
        # GQA repeat stays OUTSIDE the custom_vjp: repeat's own autodiff
        # sums dk/dv back over the head groups for free.
        rep = cfg.n_heads // cfg.n_kv_heads
        kr = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3)
        vr = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3)
        qh = q.transpose(0, 2, 1, 3)                     # [B,H,S,hd]
        h = cfg.n_heads
        out = _mx_sdpa(qh.reshape(b * h, s, hd),
                       kr.reshape(b * h, t, hd),
                       vr.reshape(b * h, t, hd),
                       policy.mx_attn_name, causal, impl)
        out = out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    else:
        out = _sdpa_chunked(q, k, v, causal=causal, q_positions=positions,
                            kv_valid_len=kv_valid_len, chunk=cfg.attn_q_chunk,
                            rules=rules)
    out = out.reshape(b, s, cfg.n_heads * hd)
    out = proj(out, p["wo"], None, policy, rules, impl, kind="row")
    if rules is not None:
        # row-parallel output lands sequence-sharded (TP path does this by
        # construction; the constraint keeps the GSPMD path on RS too, D1)
        out = rules.act(out, "batch", "seq", None)
    return out, new_cache


def init_kv_cache(cfg, batch, max_len, dtype, d_model=None):
    hd = cfg.head_dim_eff
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, dtype, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s = d ** -0.5
    if cfg.mlp == "gated_silu":
        return {
            "w_gate": jax.random.normal(k1, (d, f), dtype) * s,
            "w_up": jax.random.normal(k2, (d, f), dtype) * s,
            "w_down": jax.random.normal(k3, (f, d), dtype) * (f ** -0.5),
        }
    return {  # gelu
        "w_up": jax.random.normal(k1, (d, f), dtype) * s,
        "b_up": jnp.zeros((f,), dtype),
        "w_down": jax.random.normal(k2, (f, d), dtype) * (f ** -0.5),
        "b_down": jnp.zeros((d,), dtype),
    }


def mlp(x, p, cfg, policy, *, rules=None, impl="auto"):
    if cfg.mlp == "gated_silu" or "w_gate" in p:
        g = proj(x, p["w_gate"], None, policy, rules, impl, kind="col")
        u = proj(x, p["w_up"], None, policy, rules, impl, kind="col")
        if rules is not None:
            g = rules.act(g, "batch", None, "ff")
            u = rules.act(u, "batch", None, "ff")
        h = jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype) * u
    else:
        h = proj(x, p["w_up"], p.get("b_up"), policy, rules, impl,
                 kind="col")
        if rules is not None:
            h = rules.act(h, "batch", None, "ff")
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    out = proj(h, p["w_down"], p.get("b_down"), policy, rules, impl,
               kind="row")
    if rules is not None:
        out = rules.act(out, "batch", "seq", None)  # RS not AR (§Perf D1)
    return out
