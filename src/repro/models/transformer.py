"""Model assembly for all assigned families.

Everything is scan-over-layers (stacked [L, ...] parameters) so compile
time and HLO size are O(1) in depth — required for the 80-layer dry-run
cells. Each family provides:

    init(key)                        -> params (compute dtype)
    apply(params, tokens, aux, ...)  -> (logits, aux_loss)   [train/prefill]
    init_cache(batch, max_len)       -> decode cache
    decode_step(params, tok, cache)  -> (logits, cache)      [1 token]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..core.linear import linear
from ..core.policy import get_policy
from ..configs.base import ModelConfig
from . import layers as L
from . import moe as MOE
from . import mamba2 as M2
from . import xlstm as XL

Pytree = Any


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable
    apply: Callable          # (params, tokens, aux=None, ...) -> (logits, aux_loss)
    init_cache: Callable     # (batch, max_len) -> cache
    decode_step: Callable    # (params, tok[B], cache, ...) -> (logits[B,V], cache)
    #: decode_step also accepts tok [B, S] (block prefill: S tokens
    #: appended in one call, full [B, S, V] logits back) — attention
    #: families; recurrent families step strictly one token at a time.
    block_decode: bool = False

    def loss(self, params, tokens, aux=None, **kw):
        """Next-token cross-entropy, vocab-parallel safe.

        logsumexp reduces over the (possibly 'model'-sharded) vocab dim
        with scalar-sized collectives; the target logit is picked with an
        iota mask instead of take_along_axis, whose arbitrary-index gather
        would force GSPMD to all-gather the full logits (§Perf D1).
        """
        logits, aux_loss = self.apply(params, tokens, aux=aux, **kw)
        tgt = tokens[:, 1:]
        lg = logits[:, :-1].astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
        picked = jnp.sum(jnp.where(vocab_iota == tgt[..., None], lg, 0.0),
                         axis=-1)
        ce = jnp.mean(lse - picked)
        return ce + aux_loss


# ---------------------------------------------------------------------------
# shared embedding / head
# ---------------------------------------------------------------------------

def _init_embed(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p = {"embed": jax.random.normal(k1, (cfg.vocab_size, cfg.d_model),
                                    dtype) * 0.02}
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(
            k2, (cfg.d_model, cfg.vocab_size), dtype) * (cfg.d_model ** -0.5)
    return p


def _embed(params, tokens, cfg, rules):
    from ..parallel.tp_gemm import embed_ep_applicable, embed_lookup_ep
    if rules is not None and embed_ep_applicable(tokens, params["embed"],
                                                 rules):
        # vocab-parallel lookup; lands sequence-sharded (§Perf G3)
        return embed_lookup_ep(params["embed"], tokens, rules)
    x = params["embed"][tokens]
    if rules is not None:
        x = rules.act(x, "batch", None, None)
    return x


def _head(params, x, cfg, policy, rules, impl):
    xn = L.apply_norm(x, params["final_norm"], cfg)
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = linear(xn, w, policy=policy, impl=impl,
                    quantized=cfg.quantize_head)
    if rules is not None:
        logits = rules.logits(logits)
    return logits


# ---------------------------------------------------------------------------
# dense / MoE decoder family (deepseek, llama, qwen, stablelm, arctic,
# granite, and the LM backbone of internvl / whisper-decoder)
# ---------------------------------------------------------------------------

def _init_decoder_layer(key, cfg, dtype, cross_attn=False):
    ks = jax.random.split(key, 5)
    p = {
        "norm1": L.init_norm(cfg, dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "norm2": L.init_norm(cfg, dtype),
    }
    if cross_attn:
        p["norm_x"] = L.init_norm(cfg, dtype)
        p["xattn"] = L.init_attention(ks[1], cfg, dtype)
    if cfg.family == "moe":
        p["moe"] = MOE.init_moe(ks[2], cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[3], cfg, dtype)
    return p


def _gather_seq(x, rules, policy):
    """Megatron-SP block entry: ONE explicit all-gather of the
    sequence-sharded activations, consumed by all column-parallel GEMMs of
    the block (§Perf D2); reduce-scatter on the backward pass (D3).

    Skipped when the explicit TP-GEMM path applies — it gathers the
    fp8-quantized activations itself at 1/2-1/4 the wire bytes (D5)."""
    from ..parallel.tp_gemm import tp_applicable
    if rules is None or tp_applicable(x, rules, policy):
        return x
    return rules.gather_seq(x)


def _decoder_layer(x, lp, cfg, policy, *, positions, kv_cache=None,
                   cross_kv=None, x_cache=None, rules=None, impl="auto"):
    xn = _gather_seq(L.apply_norm(x, lp["norm1"], cfg), rules, policy)
    h, new_kv = L.attention(xn, lp["attn"], cfg, policy,
                            positions=positions,
                            kv_cache=kv_cache, rules=rules, impl=impl)
    x = x + h
    if cross_kv is not None:
        hx, _ = L.attention(
            _gather_seq(L.apply_norm(x, lp["norm_x"], cfg), rules, policy),
            lp["xattn"], cfg, policy, positions=positions,
            cross_kv=cross_kv, rules=rules, impl=impl)
        x = x + hx
    aux = jnp.zeros((), jnp.float32)
    xn = _gather_seq(L.apply_norm(x, lp["norm2"], cfg), rules, policy)
    if cfg.family == "moe":
        ff, moe_aux = MOE.moe_ffn(xn, lp["moe"], cfg, policy, rules=rules,
                                  impl=impl)
        aux = moe_aux["loss"]   # drop_frac/capacity are diagnostics
    else:
        ff = L.mlp(xn, lp["mlp"], cfg, policy, rules=rules, impl=impl)
    x = x + ff
    if rules is not None:
        x = rules.act(x, "batch", "seq", None)
    return x, aux, new_kv


def _stack_init(key, cfg, dtype, n, init_one):
    """Initialize n layers and stack leaves along a leading axis."""
    keys = jax.random.split(key, n)
    ps = [init_one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def build_dense(cfg: ModelConfig) -> ModelApi:
    policy = get_policy(cfg.policy_name)
    dtype = policy.compute_dtype

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        p = _init_embed(k1, cfg, dtype)
        p["layers"] = _stack_init(
            k2, cfg, dtype, cfg.n_layers,
            lambda k: _init_decoder_layer(k, cfg, dtype))
        p["final_norm"] = L.init_norm(cfg, dtype)
        if cfg.family == "vlm":
            p["patch_proj"] = jax.random.normal(
                k3, (cfg.frontend_dim, cfg.d_model), dtype) * (
                    cfg.frontend_dim ** -0.5)
        return p

    def apply(params, tokens, aux=None, *, rules=None, impl="auto",
              remat=False, policy_=None):
        pol = policy_ or policy
        x = _embed(params, tokens, cfg, rules)
        if cfg.family == "vlm" and aux is not None and "patches" in aux:
            pe = linear(aux["patches"], params["patch_proj"], policy=pol,
                        impl=impl, quantized=False)
            x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        b, s, _ = x.shape
        positions = jnp.arange(s)

        def body(carry, lp):
            x, aux_acc = carry
            x, aux, _ = _decoder_layer(x, lp, cfg, pol, positions=positions,
                                       rules=rules, impl=impl)
            return (x, aux_acc + aux), None

        body_fn = jax.checkpoint(body) if remat else body
        (x, aux_loss), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                        params["layers"])
        if cfg.family == "vlm" and aux is not None and "patches" in aux:
            x = x[:, -tokens.shape[1]:]
        return _head(params, x, cfg, pol, rules, impl), aux_loss

    def init_cache(batch, max_len, *, paged=None, page_size=16):
        """paged=None -> paged pool iff the policy has a packed cache
        format for this head dim; True forces paging (carrier pages
        when packing doesn't apply — the bf16 fallback); False keeps
        the contiguous carrier strip."""
        from ..serve import kv_cache as KV
        if paged is None:
            paged = KV.paged_kv_applicable(cfg, policy)
        if paged:
            kv, pt, lens = KV.init_paged_kv(cfg, policy, batch, max_len,
                                            page_size=page_size, dtype=dtype)
            stacked = jax.tree.map(lambda v: jnp.broadcast_to(
                v, (cfg.n_layers,) + v.shape).copy(), kv)
            return {"kv": stacked, "pt": pt, "lens": lens}
        kv = L.init_kv_cache(cfg, batch, max_len, dtype)
        return {"kv": jax.tree.map(
            lambda v: jnp.broadcast_to(v, (cfg.n_layers,) + v.shape).copy()
            if v.ndim else jnp.zeros((cfg.n_layers,), v.dtype), kv)}

    def decode_step(params, tok, cache, *, rules=None, impl="auto"):
        tok2 = tok if tok.ndim == 2 else tok[:, None]
        s = tok2.shape[1]
        x = _embed(params, tok2, cfg, rules)
        if "pt" in cache:
            pt, lens = cache["pt"], cache["lens"]
            positions = lens[:, None] + jnp.arange(s)  # [B, S] per-seq

            def body(carry, inp):
                x, _ = carry
                lp, kvc = inp
                x, aux, new_kv = _decoder_layer(
                    x, lp, cfg, policy, positions=positions,
                    kv_cache={"kv": kvc, "pt": pt, "lens": lens},
                    rules=rules, impl=impl)
                return (x, aux), new_kv

            (x, _), new_kv = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)),
                (params["layers"], cache["kv"]))
            new_cache = {"kv": new_kv, "pt": pt, "lens": lens + s}
        else:
            idx = cache["kv"]["idx"][0]
            positions = jnp.arange(s) + idx

            def body(carry, inp):
                x, _ = carry
                lp, kvc = inp
                x, aux, new_kv = _decoder_layer(
                    x, lp, cfg, policy, positions=positions, kv_cache=kvc,
                    rules=rules, impl=impl)
                return (x, aux), new_kv

            (x, _), new_kv = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)),
                (params["layers"], cache["kv"]))
            new_cache = {"kv": new_kv}
        logits = _head(params, x, cfg, policy, rules, impl)
        return (logits if tok.ndim == 2 else logits[:, 0]), new_cache

    return ModelApi(cfg, init, apply, init_cache, decode_step,
                    block_decode=True)


# ---------------------------------------------------------------------------
# encoder-decoder (whisper): stubbed frame embeddings -> encoder -> decoder
# ---------------------------------------------------------------------------

def build_encdec(cfg: ModelConfig) -> ModelApi:
    policy = get_policy(cfg.policy_name)
    dtype = policy.compute_dtype

    def _init_enc_layer(key):
        ks = jax.random.split(key, 2)
        return {
            "norm1": L.init_norm(cfg, dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "norm2": L.init_norm(cfg, dtype),
            "mlp": L.init_mlp(ks[1], cfg, dtype),
        }

    def init(key):
        ks = jax.random.split(key, 6)
        p = _init_embed(ks[0], cfg, dtype)
        p["frame_proj"] = jax.random.normal(
            ks[1], (cfg.d_model, cfg.d_model), dtype) * (cfg.d_model ** -0.5)
        p["enc_pos"] = jax.random.normal(
            ks[2], (cfg.enc_seq, cfg.d_model), dtype) * 0.02
        # sized for the largest assigned decode context (decode_32k)
        p["dec_pos"] = jax.random.normal(
            ks[3], (32768, cfg.d_model), dtype) * 0.02
        p["enc_layers"] = _stack_init(ks[4], cfg, dtype, cfg.n_enc_layers,
                                      _init_enc_layer)
        p["layers"] = _stack_init(
            ks[5], cfg, dtype, cfg.n_layers,
            lambda k: _init_decoder_layer(k, cfg, dtype, cross_attn=True))
        p["final_norm"] = L.init_norm(cfg, dtype)
        p["enc_norm"] = L.init_norm(cfg, dtype)
        return p

    def encode(params, frames, rules, impl):
        x = linear(frames, params["frame_proj"], policy=policy, impl=impl,
                   quantized=False)
        x = x + params["enc_pos"][None, :x.shape[1]].astype(x.dtype)
        positions = jnp.arange(x.shape[1])

        def body(x, lp):
            h, _ = L.attention(L.apply_norm(x, lp["norm1"], cfg), lp["attn"],
                               cfg, policy, positions=positions, causal=False,
                               rules=rules, impl=impl)
            x = x + h
            x = x + L.mlp(L.apply_norm(x, lp["norm2"], cfg), lp["mlp"], cfg,
                          policy, rules=rules, impl=impl)
            return x, None

        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return L.apply_norm(x, params["enc_norm"], cfg)

    def _cross_kv(params, enc_out, impl, rules):
        """Precompute K,V of the encoder output for every decoder layer."""
        b, t, _ = enc_out.shape
        hd = cfg.head_dim_eff

        def per_layer(lp):
            k = linear(enc_out, lp["xattn"]["wk"], policy=policy, impl=impl)
            v = linear(enc_out, lp["xattn"]["wv"], policy=policy, impl=impl)
            return (k.reshape(b, t, cfg.n_kv_heads, hd),
                    v.reshape(b, t, cfg.n_kv_heads, hd))

        return jax.vmap(per_layer)(params["layers"])

    def apply(params, tokens, aux=None, *, rules=None, impl="auto",
              remat=False, policy_=None):
        pol = policy_ or policy
        frames = aux["frames"]
        enc_out = encode(params, frames, rules, impl)
        ckv = _cross_kv(params, enc_out, impl, rules)
        x = _embed(params, tokens, cfg, rules)
        x = x + params["dec_pos"][None, :x.shape[1]].astype(x.dtype)
        positions = jnp.arange(x.shape[1])

        def body(carry, inp):
            x, aux_acc = carry
            lp, kv = inp
            x, aux_l, _ = _decoder_layer(x, lp, cfg, pol, positions=positions,
                                         cross_kv=kv, rules=rules, impl=impl)
            return (x, aux_acc + aux_l), None

        body_fn = jax.checkpoint(body) if remat else body
        (x, aux_loss), _ = jax.lax.scan(
            body_fn, (x, jnp.zeros((), jnp.float32)), (params["layers"], ckv))
        return _head(params, x, cfg, pol, rules, impl), aux_loss

    def init_cache(batch, max_len):
        kv = L.init_kv_cache(cfg, batch, max_len, dtype)
        hd = cfg.head_dim_eff
        stack = lambda v: (jnp.broadcast_to(
            v, (cfg.n_layers,) + v.shape).copy() if v.ndim
            else jnp.zeros((cfg.n_layers,), v.dtype))
        return {
            "kv": jax.tree.map(stack, kv),
            "cross": (
                jnp.zeros((cfg.n_layers, batch, cfg.enc_seq,
                           cfg.n_kv_heads, hd), dtype),
                jnp.zeros((cfg.n_layers, batch, cfg.enc_seq,
                           cfg.n_kv_heads, hd), dtype)),
        }

    def prefill_cache(params, frames, cache, *, rules=None, impl="auto"):
        enc_out = encode(params, frames, rules, impl)
        ck, cv = _cross_kv(params, enc_out, impl, rules)
        return {**cache, "cross": (ck.astype(dtype), cv.astype(dtype))}

    def decode_step(params, tok, cache, *, rules=None, impl="auto"):
        tok2 = tok if tok.ndim == 2 else tok[:, None]
        s = tok2.shape[1]
        x = _embed(params, tok2, cfg, rules)
        idx = cache["kv"]["idx"][0]
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], idx, s, 0)[None].astype(x.dtype)
        positions = jnp.arange(s) + idx

        def body(carry, inp):
            x, _ = carry
            lp, kvc, ck, cv = inp
            x, aux, new_kv = _decoder_layer(
                x, lp, cfg, policy, positions=positions, kv_cache=kvc,
                cross_kv=(ck, cv), rules=rules, impl=impl)
            return (x, aux), new_kv

        (x, _), new_kv = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], cache["kv"],
             cache["cross"][0], cache["cross"][1]))
        logits = _head(params, x, cfg, policy, rules, impl)
        return (logits if tok.ndim == 2 else logits[:, 0]), {**cache,
                                                             "kv": new_kv}

    api = ModelApi(cfg, init, apply, init_cache, decode_step,
                   block_decode=True)
    api.prefill_cache = prefill_cache
    return api


# ---------------------------------------------------------------------------
# xLSTM: groups of (slstm_every-1) mLSTM blocks + 1 sLSTM block
# ---------------------------------------------------------------------------

def build_xlstm(cfg: ModelConfig) -> ModelApi:
    policy = get_policy(cfg.policy_name)
    dtype = policy.compute_dtype
    per = max(cfg.slstm_every, 1)
    n_groups = cfg.n_layers // per
    n_m = per - 1  # mLSTM layers per group

    def init(key):
        ks = jax.random.split(key, 4)
        p = _init_embed(ks[0], cfg, dtype)

        def group_init(k):
            k1, k2 = jax.random.split(k)
            g = {"slstm": XL.init_slstm(k2, cfg, dtype),
                 "snorm": L.init_norm(cfg, dtype)}
            if n_m:
                g["mlstm"] = _stack_init(
                    k1, cfg, dtype, n_m, lambda kk: {
                        "blk": XL.init_mlstm(kk, cfg, dtype),
                        "norm": L.init_norm(cfg, dtype)})
            return g

        p["groups"] = _stack_init(ks[1], cfg, dtype, n_groups, group_init)
        p["final_norm"] = L.init_norm(cfg, dtype)
        return p

    def _group_fwd(x, gp, pol, caches, rules, impl):
        new_m, new_s = None, None
        if n_m:
            def mbody(carry, inp):
                x = carry
                lp, mc = inp
                h, nc = XL.mlstm_block(
                    L.apply_norm(x, lp["norm"], cfg), lp["blk"], cfg, pol,
                    cache=mc, rules=rules, impl=impl)
                return x + h, nc
            x, new_m = jax.lax.scan(
                mbody, x, (gp["mlstm"],
                           None if caches is None else caches["m"]))
        h, new_s = XL.slstm_block(L.apply_norm(x, gp["snorm"], cfg),
                                  gp["slstm"], cfg, pol,
                                  cache=None if caches is None else caches["s"],
                                  rules=rules, impl=impl)
        return x + h, {"m": new_m, "s": new_s}

    def apply(params, tokens, aux=None, *, rules=None, impl="auto",
              remat=False, policy_=None):
        pol = policy_ or policy
        x = _embed(params, tokens, cfg, rules)

        def body(carry, gp):
            x, acc = carry
            x, _ = _group_fwd(x, gp, pol, None, rules, impl)
            return (x, acc), None

        body_fn = jax.checkpoint(body) if remat else body
        (x, _), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                 params["groups"])
        return (_head(params, x, cfg, pol, rules, impl),
                jnp.zeros((), jnp.float32))

    def init_cache(batch, max_len):
        mc = XL.init_mlstm_cache(cfg, batch)
        sc = XL.init_slstm_cache(cfg, batch)
        stack = lambda t, n: jax.tree.map(
            lambda v: jnp.broadcast_to(v, n + v.shape).copy(), t)
        return {"groups": {"m": stack(mc, (n_groups, n_m)) if n_m else None,
                           "s": stack(sc, (n_groups,))}}

    def decode_step(params, tok, cache, *, rules=None, impl="auto"):
        x = _embed(params, tok[:, None], cfg, rules)

        def body(carry, inp):
            x = carry
            gp, gc = inp
            x, nc = _group_fwd(x, gp, policy, gc, rules, impl)
            return x, nc

        gc = {"m": cache["groups"]["m"], "s": cache["groups"]["s"]}
        x, ncache = jax.lax.scan(body, x, (params["groups"], gc))
        logits = _head(params, x, cfg, policy, rules, impl)
        return logits[:, 0], {"groups": ncache}

    return ModelApi(cfg, init, apply, init_cache, decode_step)


# ---------------------------------------------------------------------------
# zamba2 hybrid: groups of ``attn_every`` Mamba2 blocks + one *shared*
# attention/MLP block applied after each group (shared weights, per-group
# KV caches)
# ---------------------------------------------------------------------------

def build_hybrid(cfg: ModelConfig) -> ModelApi:
    policy = get_policy(cfg.policy_name)
    dtype = policy.compute_dtype
    per = max(cfg.attn_every, 1)
    n_groups = cfg.n_layers // per
    n_tail = cfg.n_layers - n_groups * per   # e.g. zamba2: 81 = 13*6 + 3

    def init(key):
        ks = jax.random.split(key, 5)
        p = _init_embed(ks[0], cfg, dtype)
        p["groups"] = _stack_init(
            ks[1], cfg, dtype, n_groups,
            lambda k: {"mamba": _stack_init(
                k, cfg, dtype, per, lambda kk: {
                    "blk": M2.init_mamba2(kk, cfg, dtype),
                    "norm": L.init_norm(cfg, dtype)})})
        if n_tail:
            p["tail"] = _stack_init(
                ks[4], cfg, dtype, n_tail, lambda kk: {
                    "blk": M2.init_mamba2(kk, cfg, dtype),
                    "norm": L.init_norm(cfg, dtype)})
        # the shared attention block (one set of weights)
        p["shared"] = {
            "norm1": L.init_norm(cfg, dtype),
            "attn": L.init_attention(ks[2], cfg, dtype),
            "norm2": L.init_norm(cfg, dtype),
            "mlp": L.init_mlp(ks[3], cfg, dtype),
        }
        p["final_norm"] = L.init_norm(cfg, dtype)
        return p

    def _mamba_stack(x, stacked, pol, caches, rules, impl):
        def mbody(carry, inp):
            x = carry
            lp, mc = inp
            h, nc = M2.mamba2_block(
                L.apply_norm(x, lp["norm"], cfg), lp["blk"], cfg, pol,
                cache=mc, rules=rules, impl=impl)
            return x + h, nc

        return jax.lax.scan(mbody, x, (stacked, caches))

    def _group_fwd(x, gp, shared, pol, positions, caches, rules, impl):
        x, new_m = _mamba_stack(
            x, gp["mamba"], pol, None if caches is None else caches["m"],
            rules, impl)
        h, new_kv = L.attention(L.apply_norm(x, shared["norm1"], cfg),
                                shared["attn"], cfg, pol, positions=positions,
                                kv_cache=None if caches is None else caches["kv"],
                                rules=rules, impl=impl)
        x = x + h
        x = x + L.mlp(L.apply_norm(x, shared["norm2"], cfg), shared["mlp"],
                      cfg, pol, rules=rules, impl=impl)
        return x, {"m": new_m, "kv": new_kv}

    def apply(params, tokens, aux=None, *, rules=None, impl="auto",
              remat=False, policy_=None):
        pol = policy_ or policy
        x = _embed(params, tokens, cfg, rules)
        positions = jnp.arange(x.shape[1])

        def body(carry, gp):
            x, acc = carry
            x, _ = _group_fwd(x, gp, params["shared"], pol, positions, None,
                              rules, impl)
            return (x, acc), None

        body_fn = jax.checkpoint(body) if remat else body
        (x, _), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                 params["groups"])
        if n_tail:
            x, _ = _mamba_stack(x, params["tail"], pol, None, rules, impl)
        return (_head(params, x, cfg, pol, rules, impl),
                jnp.zeros((), jnp.float32))

    def init_cache(batch, max_len):
        mc = M2.init_mamba2_cache(cfg, batch)
        kv = L.init_kv_cache(cfg, batch, max_len, dtype)
        stack = lambda t, n: jax.tree.map(
            lambda v: (jnp.broadcast_to(v, n + v.shape).copy()
                       if v.ndim else jnp.zeros(n, v.dtype)), t)
        cache = {"groups": {"m": stack(mc, (n_groups, per)),
                            "kv": stack(kv, (n_groups,))}}
        if n_tail:
            cache["tail"] = stack(mc, (n_tail,))
        return cache

    def decode_step(params, tok, cache, *, rules=None, impl="auto"):
        x = _embed(params, tok[:, None], cfg, rules)
        idx = cache["groups"]["kv"]["idx"][0]
        positions = jnp.arange(1) + idx

        def body(carry, inp):
            x = carry
            gp, gc = inp
            x, nc = _group_fwd(x, gp, params["shared"], policy, positions,
                               gc, rules, impl)
            return x, nc

        x, ncache = jax.lax.scan(body, x, (params["groups"],
                                           cache["groups"]))
        new_cache = {"groups": ncache}
        if n_tail:
            x, ntail = _mamba_stack(x, params["tail"], policy,
                                    cache["tail"], rules, impl)
            new_cache["tail"] = ntail
        logits = _head(params, x, cfg, policy, rules, impl)
        return logits[:, 0], new_cache

    return ModelApi(cfg, init, apply, init_cache, decode_step)
