"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable —
runs on the shared chunked-GLA engine) and sLSTM (scalar memory with true
recurrent gate feedback — a sequential lax.scan).

Simplifications recorded in DESIGN.md §5:
  * mLSTM input gate uses sigmoid (not exp) — keeps the chunked form
    stable in f32 without the paper's running max-stabilizer; the
    normalizer column is kept, so outputs remain scale-invariant.
  * sLSTM keeps the exponential gating + stabilizer state of the paper,
    with block-diagonal (per-head) recurrent weights.

Projections quantize per policy (expanding GEMM); all recurrent state is
f32 (the accumulate-wide rule).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.linear import linear
from .layers import rms_norm
from .ssm import chunked_gla, gla_step

__all__ = ["init_mlstm", "mlstm_block", "init_slstm", "slstm_block",
           "init_mlstm_cache", "init_slstm_cache"]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg):
    di = 2 * cfg.d_model            # expansion 2
    h = cfg.n_heads
    p = di // h
    return di, h, p


def init_mlstm(key, cfg, dtype):
    d = cfg.d_model
    di, h, p = _mlstm_dims(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    si = di ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * s,  # xm | z
        "wq": jax.random.normal(ks[1], (di, di), dtype) * si,
        "wk": jax.random.normal(ks[2], (di, di), dtype) * si,
        "wv": jax.random.normal(ks[3], (di, di), dtype) * si,
        "w_gates": jax.random.normal(ks[4], (di, 2 * h), jnp.float32) * si,
        "b_gates": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(ks[5], (di, d), dtype) * si,
    }


def mlstm_block(x, p, cfg, policy, *, cache=None, rules=None, impl="auto"):
    b, s, d = x.shape
    di, h, pd = _mlstm_dims(cfg)
    proj = linear(x, p["in_proj"], policy=policy, impl=impl)
    xm, z = proj[..., :di], proj[..., di:]

    q = linear(xm, p["wq"], policy=policy, impl=impl).reshape(b, s, h, pd)
    k = linear(xm, p["wk"], policy=policy, impl=impl).reshape(b, s, h, pd)
    v = linear(xm, p["wv"], policy=policy, impl=impl).reshape(b, s, h, pd)
    k = k * (pd ** -0.5)

    gates = jnp.dot(xm.astype(jnp.float32), p["w_gates"]) + p["b_gates"]
    ig = jax.nn.sigmoid(gates[..., :h])            # [B,S,H]
    log_f = jax.nn.log_sigmoid(gates[..., h:])     # [B,S,H] <= 0

    khat = k.astype(jnp.float32) * ig[..., None]
    # normalizer column: v_aug = [v, 1]
    v_aug = jnp.concatenate(
        [v.astype(jnp.float32), jnp.ones((b, s, h, 1), jnp.float32)], -1)

    if cache is None:
        y, hT = chunked_gla(q, khat, v_aug, log_f, None, chunk=128)
    else:
        y, hT = gla_step(q[:, 0], khat[:, 0], v_aug[:, 0], log_f[:, 0],
                         cache["h"])
        y = y[:, None]
    new_cache = {"h": hT}

    num, den = y[..., :pd], y[..., pd:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y, p["norm_scale"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return linear(y, p["out_proj"], policy=policy, impl=impl), new_cache


def init_mlstm_cache(cfg, batch):
    di, h, pd = _mlstm_dims(cfg)
    return {"h": jnp.zeros((batch, h, pd, pd + 1), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM (sequential, exponential gating with stabilizer)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    pd = d // h
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    return {
        # input projections for z,i,f,o stacked: [D, 4D]
        "w_in": jax.random.normal(ks[0], (d, 4 * d), dtype) * s,
        # block-diagonal recurrent weights per head: [4, H, P, P]
        "r": jax.random.normal(ks[1], (4, h, pd, pd), jnp.float32) * (pd ** -0.5),
        "b": jnp.concatenate([jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((d,))]),
        "norm_scale": jnp.ones((d,), dtype),
        "out_proj": jax.random.normal(ks[2], (d, d), dtype) * s,
    }


def _slstm_cell(carry, zifo_t, r, h_heads, pd):
    """One sLSTM step. carry = (hprev [B,D], c, n, m); zifo_t [B,4D]."""
    hprev, c, n, m = carry
    b, d = hprev.shape
    hh = hprev.reshape(b, h_heads, pd)
    rec = jnp.einsum("bhp,ghpq->bghq", hh, r).reshape(b, 4, d)
    zr, ir, fr, orr = [zifo_t[:, i * d:(i + 1) * d] + rec[:, i]
                       for i in range(4)]
    z = jnp.tanh(zr)
    log_i = ir
    log_f = jax.nn.log_sigmoid(fr)
    mnew = jnp.maximum(log_f + m, log_i)           # stabilizer
    ip = jnp.exp(log_i - mnew)
    fp = jnp.exp(log_f + m - mnew)
    c = fp * c + ip * z
    n = fp * n + ip
    hout = jax.nn.sigmoid(orr) * c / jnp.maximum(jnp.abs(n), 1.0)
    return (hout, c, n, mnew), hout


def slstm_block(x, p, cfg, policy, *, cache=None, rules=None, impl="auto"):
    b, s, d = x.shape
    h = cfg.n_heads
    pd = d // h
    zifo = linear(x, p["w_in"], policy=policy, impl=impl)
    zifo = zifo.astype(jnp.float32) + p["b"]

    if cache is None:
        carry0 = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(3)) + (
            jnp.full((b, d), -1e9, jnp.float32),)
        carry0 = (carry0[0], carry0[1], carry0[2], carry0[3])
        cell = lambda cr, zt: _slstm_cell(cr, zt, p["r"], h, pd)
        carry, ys = jax.lax.scan(cell, carry0, zifo.swapaxes(0, 1))
        y = ys.swapaxes(0, 1)
    else:
        carry0 = (cache["hid"], cache["c"], cache["n"], cache["m"])
        carry, y1 = _slstm_cell(carry0, zifo[:, 0], p["r"], h, pd)
        y = y1[:, None]
    new_cache = {"hid": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}

    y = rms_norm(y.astype(x.dtype), p["norm_scale"], cfg.norm_eps)
    return linear(y, p["out_proj"], policy=policy, impl=impl), new_cache


def init_slstm_cache(cfg, batch):
    d = cfg.d_model
    return {"hid": jnp.zeros((batch, d), jnp.float32),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e9, jnp.float32)}
