from .api import build_model
