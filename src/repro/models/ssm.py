"""Chunked gated linear attention — shared recurrence engine for Mamba2 (SSD)
and xLSTM's mLSTM.

Both families are instances of

    H_t = a_t * H_{t-1} + khat_t  vhat_t^T        (state [dk, dv] per head)
    y_t = qhat_t @ H_t

  * Mamba2/SSD:  a = exp(dt*A),  khat = dt*B_t,  vhat = x_t,  qhat = C_t
  * mLSTM:       a = f_t,        khat = i_t*k_t, vhat = [v_t, 1] (normalizer
                 column), qhat = q_t

The chunked algorithm (SSD, Dao & Gu 2024) computes the quadratic form
within chunks and carries the state across chunks — O(S*Q) memory instead
of O(S^2) (or O(S * dk * dv) for a naive scan). All internal math is f32
with log-space decay differences (numerical hygiene for low-precision
training); projections around it are quantized per policy.

``glu_step`` is the O(1) decode update used by serve_step at 500k context —
the reason SSM/hybrid archs run the ``long_500k`` cell while pure-attention
archs must skip it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["chunked_gla", "gla_step"]


def chunked_gla(q, k, v, log_a, h0=None, *, chunk: int = 128):
    """q,k [B,S,H,dk]; v [B,S,H,dv]; log_a [B,S,H] (<= 0).

    Returns (y [B,S,H,dv], hT [B,H,dk,dv]).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if s % chunk:
        chunk = s  # single chunk fallback (smoke shapes)
    nc = s // chunk

    q = q.astype(jnp.float32).reshape(b, nc, chunk, h, dk)
    k = k.astype(jnp.float32).reshape(b, nc, chunk, h, dk)
    v = v.astype(jnp.float32).reshape(b, nc, chunk, h, dv)
    la = log_a.astype(jnp.float32).reshape(b, nc, chunk, h)

    if h0 is None:
        h0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(hprev, inp):
        qc, kc, vc, lc = inp                       # [B,Q,H,*]
        lcum = jnp.cumsum(lc, axis=1)              # within-chunk log decay
        # intra-chunk quadratic term
        att = jnp.einsum("bqhd,bjhd->bhqj", qc, kc)
        diff = (lcum.transpose(0, 2, 1)[:, :, :, None]
                - lcum.transpose(0, 2, 1)[:, :, None, :])  # [B,H,Q,Q]
        dec = jnp.exp(jnp.where(causal[None, None], diff, -jnp.inf))
        y_intra = jnp.einsum("bhqj,bjhv->bqhv", att * dec, vc)
        # inter-chunk contribution from carried state
        qdec = qc * jnp.exp(lcum)[..., None]
        y_inter = jnp.einsum("bqhd,bhdv->bqhv", qdec, hprev)
        # state update: decay to end of chunk
        w = jnp.exp(lcum[:, -1:, :] - lcum)        # [B,Q,H]
        dh = jnp.einsum("bjhd,bjhv->bhdv", kc * w[..., None], vc)
        hnew = jnp.exp(lcum[:, -1, :])[..., None, None] * hprev + dh
        return hnew, y_intra + y_inter

    # scan over chunks (axis 1)
    inp = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
           la.swapaxes(0, 1))
    hT, ys = jax.lax.scan(body, h0, inp)
    y = ys.swapaxes(0, 1).reshape(b, s, h, dv)
    return y, hT


def gla_step(q, k, v, log_a, h):
    """Single-token decode update. q,k [B,H,dk]; v [B,H,dv]; log_a [B,H];
    h [B,H,dk,dv]. Returns (y [B,H,dv], h')."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    h = a * h + jnp.einsum("bhd,bhv->bhdv", k, v)
    y = jnp.einsum("bhd,bhdv->bhv", q, h)
    return y, h
