"""AdamW with low-precision state options — the optimizer-side half of the
paper's story.

Master weights and moments can each be stored narrow (bf16/fp16) while the
*update arithmetic* is always f32 ("accumulate wide, store narrow" — the
ExSdotp rule applied to the optimizer). Optional stochastic rounding on the
param downcast removes the bias that RNE introduces when |update| << ulp —
the standard companion trick for low-precision training at scale.

State layout mirrors the param tree leaf-for-leaf, so ZeRO partitioning is
just "shard the state like the params" (parallel/sharding.py) and gradient
reduce-scatter falls out of GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_dtype: jnp.dtype = jnp.float32
    moment_dtype: jnp.dtype = jnp.float32
    stochastic_round: bool = False
    warmup_steps: int = 100
    schedule: str = "cosine"      # cosine | constant
    total_steps: int = 10_000


def _lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 0.5 * (1 + jnp.cos(jnp.pi * t))
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def adamw_init(params, cfg: AdamWConfig):
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(
            lambda p: p.astype(cfg.master_dtype), params),
        "m": jax.tree.map(
            lambda p: jnp.zeros(p.shape, cfg.moment_dtype), params),
        "v": jax.tree.map(
            lambda p: jnp.zeros(p.shape, cfg.moment_dtype), params),
    }


def _stochastic_cast(x_f32, dtype, key):
    """Stochastic rounding f32 -> dtype (unbiased downcast).

    The next representable value toward ``x`` is computed sign-aware on
    the sign/magnitude encoding: incrementing raw bits only walks the
    value lattice within one sign, and ``lo == -0.0`` (raw 0x8000)
    decrements straight into the NaN space (0x7FFF) if treated as "a
    negative number, step the integer".  Split sign bit and magnitude,
    step the magnitude, and flip the sign when the step crosses zero —
    updates in (-ulp, 0) land on -0.0 and must round toward the first
    *negative* subnormal, not truncate.
    """
    lo = x_f32.astype(dtype)
    lof = lo.astype(jnp.float32)
    nbits = 16 if dtype in (jnp.bfloat16, jnp.float16) else 8
    ui = jnp.uint16 if nbits == 16 else jnp.uint8
    bits = jax.lax.bitcast_convert_type(lo, ui).astype(jnp.int32)
    sign = bits >> (nbits - 1)
    mag = bits & ((1 << (nbits - 1)) - 1)
    up = x_f32 > lof          # step toward +inf (else toward -inf)
    # magnitude delta for a value-lattice step: +1 if the step moves
    # away from zero on this sign, -1 if toward zero
    mag_step = jnp.where(sign == 0, jnp.where(up, 1, -1),
                         jnp.where(up, -1, 1))
    nmag = mag + mag_step
    nsign = jnp.where(nmag < 0, 1 - sign, sign)   # ±0 crossing
    nmag = jnp.abs(nmag)
    nxt = jax.lax.bitcast_convert_type(
        ((nsign << (nbits - 1)) | nmag).astype(ui), dtype).astype(jnp.float32)
    span = nxt - lof
    frac = jnp.where(span != 0, (x_f32 - lof) / jnp.where(span == 0, 1, span),
                     0.0)
    u = jax.random.uniform(key, x_f32.shape)
    return jnp.where(u < jnp.abs(frac), nxt, lof).astype(dtype)


def adamw_update(grads, opt_state, params, cfg: AdamWConfig,
                 *, skip: Optional[jax.Array] = None, rng=None):
    """One step. ``skip`` (bool scalar) freezes everything (loss-scale
    overflow); gradients are f32-upcast, globally clipped, and every
    arithmetic op runs in f32 regardless of storage dtypes."""
    step = opt_state["step"]
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)))
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    gf = jax.tree.map(lambda g: g * clip, gf)
    lr = _lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)
    if skip is None:
        skip = jnp.zeros((), bool)

    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = (list(jax.random.split(rng, len(leaves))) if rng is not None
            else [None] * len(leaves))
    keytree = jax.tree_util.tree_unflatten(treedef, keys)

    def upd(g, m, v, master, p, key):
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = mf / bc1
        vhat = vf / bc2
        mw = master.astype(jnp.float32)
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * mw
        neww = mw - lr * upd
        # skip: keep previous state bit-for-bit
        neww = jnp.where(skip, mw, neww)
        mf = jnp.where(skip, m.astype(jnp.float32), mf)
        vf = jnp.where(skip, v.astype(jnp.float32), vf)
        if cfg.stochastic_round and key is not None and p.dtype in (
                jnp.bfloat16, jnp.float16):
            newp = _stochastic_cast(neww, p.dtype, key)
        else:
            newp = neww.astype(p.dtype)
        return (mf.astype(cfg.moment_dtype), vf.astype(cfg.moment_dtype),
                neww.astype(cfg.master_dtype), newp)

    out = jax.tree.map(upd, gf, opt_state["m"], opt_state["v"],
                       opt_state["master"], params, keytree,
                       is_leaf=lambda x: x is None)
    m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    newp = jax.tree.map(lambda o: o[3], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"step": step + jnp.where(skip, 0, 1), "master": master,
                 "m": m, "v": v}
    return newp, new_state, {"grad_norm": gnorm, "lr": lr}
