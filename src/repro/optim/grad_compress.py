"""FP8-compressed cross-replica gradient reduction with error feedback.

The paper's thesis — ship narrow, accumulate wide — applied to the
*network*: gradients are quantized to FP8-E5M2 (per-leaf scale) before the
data-parallel reduction, halving/quartering ICI-DCN bytes; partial sums are
accumulated in f32 (expanding accumulation); the quantization residual is
carried to the next step (error feedback), which keeps SGD convergence
unbiased to first order.

Built on shard_map so the collective is explicit: used by the DDP-style
trainer variant and by the cross-pod stage of the hierarchical reduction
(within-pod reductions stay full precision — they're cheap on ICI; the
pod axis is the slow hop that benefits).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from ..compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["compressed_psum_mean", "error_feedback_init"]


def error_feedback_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize_leaf(g, q_dtype):
    amax = jnp.max(jnp.abs(g))
    maxn = jnp.float32(jnp.finfo(q_dtype).max)
    s = jnp.where(amax > 0, amax / maxn, 1.0)
    return (g / s).astype(q_dtype), s


def compressed_psum_mean(grads, ef, mesh: Mesh, axis: str,
                         q_dtype=jnp.float8_e5m2):
    """Mean-reduce ``grads`` over mesh axis ``axis`` in compressed form.

    grads: tree of f32 leaves, identical (replica-local) on every member of
    ``axis``. ef: error-feedback tree (same shapes, f32). Returns
    (reduced_grads_f32, new_ef).

    Inside the shard_map: g+ef is quantized to q_dtype, all-gathered in
    narrow form, de-quantized and accumulated f32 (expanding accumulation),
    and the local quantization error becomes the new ef.
    """
    n = mesh.shape[axis]

    def leaf_fn(g, e):
        gc = g.astype(jnp.float32) + e
        q, s = _quantize_leaf(gc, q_dtype)
        new_e = gc - q.astype(jnp.float32) * s
        # narrow all-gather (the compressed wire format), f32 accumulate
        qs = jax.lax.all_gather(q, axis)            # [n, ...] narrow
        ss = jax.lax.all_gather(s, axis)            # [n] scales
        red = jnp.tensordot(ss, qs.astype(jnp.float32), axes=((0,), (0,)))
        return red / n, new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(ef)[0]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(tuple(P() for _ in flat_g), tuple(P() for _ in flat_e)),
        out_specs=(tuple(P() for _ in flat_g), tuple(P() for _ in flat_e)),
        check_vma=False)
    def run_flat(gs, es):
        outs = [leaf_fn(g, e) for g, e in zip(gs, es)]
        return tuple(o[0] for o in outs), tuple(o[1] for o in outs)

    red, new_ef = run_flat(tuple(flat_g), tuple(flat_e))
    return (jax.tree_util.tree_unflatten(treedef, list(red)),
            jax.tree_util.tree_unflatten(treedef, list(new_ef)))
