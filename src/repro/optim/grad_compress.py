"""Compressed cross-replica gradient reduction with error feedback.

The paper's thesis — ship narrow, accumulate wide — applied to the
*network*: gradients are quantized before the data-parallel reduction,
partial sums are accumulated in f32 (expanding accumulation), and the
quantization residual is carried to the next step (error feedback),
which keeps SGD convergence unbiased to first order.

Two wire formats (DESIGN.md §13):

* **per-leaf FP8** (legacy): each leaf ships as FP8-E5M2 under a single
  f32 scale.  One outlier element collapses the whole leaf into the
  subnormal mud — the exact failure mode the MX sweep measured 2–3
  orders worse than group-32 scaling.
* **MX groups** (``mx=`` / ``Policy.mx_dp_grad``): each leaf flattens,
  pads to whole groups of 32 (the established pad-and-mask convention:
  zero padding quantizes to zero payload under the neutral scale, so
  the mean is exact after the slice), and ships as *packed* codec
  payloads (MXFP6: 0.75 B/elem, MXFP4: 0.5 B/elem) next to a packed
  E8M0 byte grid (one uint8 per group).  The receive side dequantizes
  per group (exact — pow2) and accumulates f32 in chunks (Wang et al.
  1812.08011: chunk-based wide accumulation suffices on the update
  path), and the per-leaf error feedback absorbs the group residual.

Non-finite convention (both wires): a leaf whose amax is inf/NaN keeps
a *neutral* scale (per-leaf path) or gets the E8M0 NaN scale poisoning
its group (MX path), so the non-finite values reach the reduced output
and from there the loss-scale/finite-guard skip — instead of an ``inf``
scale zero-laundering the payload.  An error-feedback leaf that picked
up non-finite residual is reset to zero rather than carried: EF state
must never poison future (finite) steps.

Built on shard_map so the collective is explicit: used by the DDP-style
trainer variant (``make_train_step(dp_compress=True)``) and by the
cross-pod stage of the hierarchical reduction (within-pod reductions
stay full precision — they're cheap on ICI; the pod axis is the slow
hop that benefits).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from ..compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..core.formats import get_mx_format
# the one quantize/dequantize implementation every explicit wire shares
# (payload in the element format's native byte dtype or packed codec
# lanes, E8M0 byte grids, NaN-scale poison) — DESIGN.md §9/§13
from ..parallel.tp_gemm import _deq_mx, _quant_mx

__all__ = ["compressed_psum_mean", "error_feedback_init",
           "dp_wire_bytes_per_step"]


def error_feedback_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize_leaf(g, q_dtype):
    amax = jnp.max(jnp.abs(g))
    maxn = jnp.float32(jnp.finfo(q_dtype).max)
    # non-finite amax -> scale 1: inf/NaN propagate to the loss-scale
    # skip instead of an inf scale flushing the payload to zero and
    # NaN-poisoning the error feedback (matches _quant_local/_a2a_sum
    # in parallel/tp_gemm.py)
    s = jnp.where((amax > 0) & jnp.isfinite(amax), amax / maxn, 1.0)
    return (g / s).astype(q_dtype), s


def _reset_nonfinite_ef(e):
    """Error feedback must stay finite: a residual computed from inf/NaN
    gradients (inf - NaN = NaN) would otherwise re-poison every later
    step after the bad batch is long gone.  The poisoned *wire* output
    still reaches the skip logic this step; only the carried state is
    scrubbed."""
    return jnp.where(jnp.all(jnp.isfinite(e)), e, jnp.zeros_like(e))


def _chunked_sum(x, chunk: int):
    """Sum ``x[n, ...]`` over axis 0 in f32, ``chunk`` sources at a time
    (partials of partials — the 1812.08011 chunk-based accumulation
    structure, carried wide).  ``n`` is static inside shard_map, so the
    chunk loop unrolls at trace time."""
    n = x.shape[0]
    parts = [jnp.sum(x[i:i + chunk].astype(jnp.float32), axis=0)
             for i in range(0, n, chunk)]
    acc = parts[0]
    for p in parts[1:]:
        acc = acc + p
    return acc


def _leaf_mx(g, e, mx, axis, n, chunk):
    """One leaf over the MX wire: flatten → pad to whole groups →
    packed payload + E8M0 byte grid all-gather → per-group dequant →
    chunked f32 accumulation → slice the padding back off."""
    gc = g.astype(jnp.float32) + e
    flat = gc.reshape(-1)
    size = flat.shape[0]
    kp = -(-size // mx.group) * mx.group
    fp = jnp.pad(flat, (0, kp - size))
    q, s8 = _quant_mx(fp, mx)                   # packed bytes + u8 codes
    deq = _deq_mx(q, s8, mx)
    new_e = _reset_nonfinite_ef((fp - deq)[:size].reshape(g.shape))
    qs = jax.lax.all_gather(q, axis)            # [n, kp*w/8] narrow wire
    ss = jax.lax.all_gather(s8, axis)           # [n, kp/group] E8M0 bytes
    red = _chunked_sum(_deq_mx(qs, ss, mx), chunk)
    return (red / n)[:size].reshape(g.shape), new_e


def _leaf_fp8(g, e, q_dtype, axis, n):
    """One leaf over the legacy per-leaf FP8 wire (single f32 scale)."""
    gc = g.astype(jnp.float32) + e
    q, s = _quantize_leaf(gc, q_dtype)
    new_e = _reset_nonfinite_ef(gc - q.astype(jnp.float32) * s)
    # narrow all-gather (the compressed wire format), f32 accumulate
    qs = jax.lax.all_gather(q, axis)            # [n, ...] narrow
    ss = jax.lax.all_gather(s, axis)            # [n] scales
    red = jnp.tensordot(ss, qs.astype(jnp.float32), axes=((0,), (0,)))
    return red / n, new_e


def compressed_psum_mean(grads, ef, mesh: Mesh, axis: str,
                         q_dtype=jnp.float8_e5m2, mx=None, chunk: int = 4):
    """Mean-reduce ``grads`` over mesh axis ``axis`` in compressed form.

    grads: tree of f32 leaves, identical (replica-local) on every member
    of ``axis``. ef: error-feedback tree (same shapes, f32). Returns
    (reduced_grads_f32, new_ef).

    Inside the shard_map: g+ef is quantized, all-gathered in narrow form
    (with ``mx`` — an MX format name / ``MXFormat``, typically
    ``Policy.mx_dp_grad`` — as packed codec payloads + E8M0 byte grids
    over groups of 32; otherwise as per-leaf FP8 with one f32 scale),
    de-quantized and accumulated f32 (expanding accumulation; ``chunk``
    sources per partial on the MX path), and the local quantization
    error becomes the new ef.  Non-finite gradients propagate to the
    output (scale-1 / NaN-scale poison conventions); non-finite EF
    leaves are reset, never carried.
    """
    n = mesh.shape[axis]
    mxf = get_mx_format(mx) if mx is not None else None

    def leaf_fn(g, e):
        if mxf is not None:
            return _leaf_mx(g, e, mxf, axis, n, chunk)
        return _leaf_fp8(g, e, q_dtype, axis, n)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(ef)[0]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(tuple(P() for _ in flat_g), tuple(P() for _ in flat_e)),
        out_specs=(tuple(P() for _ in flat_g), tuple(P() for _ in flat_e)),
        check_vma=False)
    def run_flat(gs, es):
        outs = [leaf_fn(g, e) for g, e in zip(gs, es)]
        return tuple(o[0] for o in outs), tuple(o[1] for o in outs)

    red, new_ef = run_flat(tuple(flat_g), tuple(flat_e))
    return (jax.tree_util.tree_unflatten(treedef, list(red)),
            jax.tree_util.tree_unflatten(treedef, list(new_ef)))


def dp_wire_bytes_per_step(grads, mx=None, q_dtype=jnp.float8_e5m2) -> int:
    """Bytes one replica ships per step for ``grads`` on the compressed
    wire: packed payload + E8M0 grid per whole-group-padded leaf (MX),
    or one narrow element per entry + a 4-byte scale per leaf (FP8).
    Pure shape math — the honest number the wire-bytes gate tracks."""
    total = 0
    if mx is not None:
        mxf = get_mx_format(mx)
        w = mxf.elem.width
        for g in jax.tree.leaves(grads):
            kp = -(-g.size // mxf.group) * mxf.group
            total += kp * w // 8 + kp // mxf.group
    else:
        bpe = jnp.dtype(q_dtype).itemsize
        for g in jax.tree.leaves(grads):
            total += g.size * bpe + 4
    return total
