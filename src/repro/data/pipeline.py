"""Deterministic synthetic token pipeline — host-sharded, resumable.

Real multi-pod training feeds per-host shards of the global batch; here the
"dataset" is a stateless hash of (step, global position), which gives:
  * exact resume after checkpoint restore (skip-to-step is free),
  * bit-identical data under any re-sharding (elastic re-scale safe),
  * no filesystem dependency inside the container.

The same interface (``global_batch_at_step``/``host_batch_at_step``) is
what a real tokenized-corpus loader would implement.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticTokens"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticTokens:
    """tokens[step, i, t] = splitmix-style hash — O(1) random access."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _hash(self, x: np.ndarray) -> np.ndarray:
        x = (x ^ np.uint64(self.cfg.seed * 0x9E3779B97F4A7C15)).astype(np.uint64)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        x = x ^ (x >> np.uint64(31))
        return x

    def global_batch_at_step(self, step: int) -> np.ndarray:
        c = self.cfg
        idx = (np.uint64(step) * np.uint64(c.global_batch * c.seq_len)
               + np.arange(c.global_batch * c.seq_len, dtype=np.uint64))
        toks = self._hash(idx) % np.uint64(c.vocab_size)
        return toks.reshape(c.global_batch, c.seq_len).astype(np.int32)

    def host_batch_at_step(self, step: int, host_id: int,
                           n_hosts: int) -> np.ndarray:
        full = self.global_batch_at_step(step)
        per = self.cfg.global_batch // n_hosts
        return full[host_id * per:(host_id + 1) * per]
