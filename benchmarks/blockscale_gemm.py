"""Block-scaled vs per-tensor ExSdotp GEMM: accuracy + throughput sweep.

Beyond-paper extension of Table IV (accuracy of expanding chains) to
GEMM granularity: the same fused multiply-narrow/accumulate-wide/round-
once structure, with quantization scales at per-tensor vs per-block
(row-tile × K-tile) granularity.  The workload is an outlier-tile sweep:
a unit-scale Gaussian matrix with a fraction of tiles boosted by 2^E,
E swept past each format's dynamic range (FP8alt E4M3 ~2^18, FP8 E5M2
~2^32) — the regime where one outlier flushes the per-tensor-scaled
tensor to zero but leaves per-block untouched.

Reported per (format, E): row-normalized MSE for per-tensor and
per-block, their ratio, and wall-clock of the jitted fused GEMM vs the
separate quantize→GEMM pipeline (the fused path also saves the
quantized tensor's HBM round-trip).

A third sweep (``tp_sweep``) measures the same protocol *across the
wire*: the shard_map TP column GEMM with sequence-sharded activations
on a forced (data=2, model=4) host mesh, comparing the ``hfp8`` wire
(per-shard-tensor scales) against ``hfp8_block`` (per-block scale grids
riding alongside the fp8 payload) — block scaling × sequence
parallelism composed (DESIGN.md §3).

A fourth sweep (``mx_sweep``) pushes scale granularity to the MX limit
(DESIGN.md §8): per-(row × group-of-32-along-K) E8M0 shared exponents,
for all five predefined MX formats, against per-tensor scaling and
128×128 block scaling.  The workload plants one hot 32-column group per
128×128 tile — exactly the granularity block scaling cannot resolve (the
hot group drags its whole tile's window up) but group-32 can.

Run:
    PYTHONPATH=src python -m benchmarks.blockscale_gemm [--quick]
"""
from __future__ import annotations

import sys
import time

import numpy as np


def _time_us(fn, *args, warmup=2, iters=10):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def outlier_matrix(rng, m, k, bs, emax, frac=0.15):
    x = rng.normal(0, 1, (m, k))
    mask = rng.random((m // bs, k // bs)) < frac
    x *= np.kron(np.where(mask, 2.0 ** emax, 1.0), np.ones((bs, bs)))
    return x


def accuracy_sweep(quick=False):
    import jax.numpy as jnp
    from repro.core.scaling import BlockScaleConfig
    from repro.kernels import ops, ref

    m, k, n, bs = (128, 128, 64, 32) if quick else (512, 512, 256, 64)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
    cfg = BlockScaleConfig(block_m=bs, block_n=bs, block_k=bs)
    print("format,outlier_exp,nmse_per_tensor,nmse_per_block,ratio")
    for fname, q in [("fp8alt_e4m3", jnp.float8_e4m3),
                     ("fp8_e5m2", jnp.float8_e5m2)]:
        for emax in (0, 8, 16, 24, 32, 40):
            a = jnp.asarray(outlier_matrix(rng, m, k, bs, emax), jnp.float32)
            exact = np.asarray(a, np.float64) @ np.asarray(b, np.float64)

            def row_nmse(out):
                err = np.asarray(out, np.float64) - exact
                pw = (exact ** 2).sum(1)
                return float(np.mean((err ** 2).sum(1)[pw > 0] / pw[pw > 0]))

            blk = ops.blockscale_gemm(a, b, q_dtype_a=q, cfg=cfg)
            aq, sa = ops.quantize_tensor(a, q)
            bq, sb = ops.quantize_tensor(b, q)
            pt = ref.exsdotp_gemm_ref(aq, bq, sa * sb)
            e_b, e_t = row_nmse(blk), row_nmse(pt)
            print(f"{fname},{emax},{e_t:.3e},{e_b:.3e},"
                  f"{e_t / max(e_b, 1e-300):.1f}")


def throughput(quick=False):
    import jax
    import jax.numpy as jnp
    from repro.core.scaling import BlockScaleConfig
    from repro.kernels import ops

    m = k = n = 512 if quick else 1024
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(0, 1, (m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
    cfg = BlockScaleConfig()
    q = jnp.float8_e4m3

    @jax.jit
    def fused(a, b):
        return ops.blockscale_gemm(a, b, q_dtype_a=q, cfg=cfg)

    @jax.jit
    def two_pass(a, b):
        aq, sa = ops.quantize_tensor(a, q)
        bq, sb = ops.quantize_tensor(b, q)
        return ops.exsdotp_gemm(aq, bq, sa * sb)

    print("name,us_per_call,shape")
    print(f"blockscale_fused,{_time_us(fused, a, b):.1f},{m}x{k}x{n}")
    print(f"per_tensor_two_pass,{_time_us(two_pass, a, b):.1f},{m}x{k}x{n}")


def tp_sweep(quick=False):
    """Block scaling × TP/SP: outlier accuracy across the fp8 wire.

    Requires >= 8 host devices — ``main()`` forces them via XLA_FLAGS
    before the first jax import.
    """
    import jax
    import jax.numpy as jnp
    from repro.compat import make_mesh, set_mesh
    from repro.core.policy import get_policy
    from repro.parallel.sharding import make_rules
    from repro.parallel.tp_gemm import tp_column_linear

    if len(jax.devices()) < 8:
        print("tp_sweep: skipped (needs 8 devices; run via __main__)")
        return
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = make_rules(mesh, seq_shard=True)
    b, s, k, n, bs = (4, 32, 128, 128, 32) if quick else (4, 64, 256, 256, 64)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.3, (k, n)), jnp.float32).astype(
        jnp.bfloat16)
    print("wire,outlier_exp,nmse_per_tensor,nmse_per_block,ratio")
    for emax in (0, 8, 16, 24, 32):
        x = jnp.asarray(outlier_matrix(rng, b * s, k, bs, emax)
                        .reshape(b, s, k), jnp.float32).astype(jnp.bfloat16)
        exact = (np.asarray(x, np.float64).reshape(-1, k)
                 @ np.asarray(w, np.float64))

        def row_nmse(y):
            err = np.asarray(y, np.float64).reshape(-1, n) - exact
            pw = (exact ** 2).sum(1)
            nz = pw > 0
            return float(np.mean((err ** 2).sum(1)[nz] / pw[nz]))

        with set_mesh(mesh):
            yb = jax.jit(lambda x, w: tp_column_linear(
                x, w, get_policy("hfp8_block"), rules))(x, w)
            yt = jax.jit(lambda x, w: tp_column_linear(
                x, w, get_policy("hfp8"), rules))(x, w)
        e_b, e_t = row_nmse(yb), row_nmse(yt)
        print(f"tp_column,{emax},{e_t:.3e},{e_b:.3e},"
              f"{e_t / max(e_b, 1e-300):.1f}")


def mx_outlier_matrix(rng, m, k, group, emax, tile=128):
    """Unit Gaussians with one hot 32-column group per (tile × tile) tile
    — sub-tile outlier granularity, the regime MX groups exist for."""
    x = rng.normal(0, 1, (m, k))
    for ti in range(max(1, m // tile)):
        for tj in range(max(1, k // tile)):
            i = tile * ti + rng.integers(min(tile, m))
            j = tile * tj + group * rng.integers(max(1, min(tile, k) // group))
            x[i, j:j + group] *= 2.0 ** emax
    return x


def mx_sweep(quick=False):
    """Group-32 (MX) vs per-tensor vs 128×128 block scaling accuracy."""
    import jax.numpy as jnp
    from repro.core.formats import MX_FORMATS
    from repro.core.scaling import BlockScaleConfig
    from repro.kernels import ops, ref

    m, k, n = (128, 128, 64) if quick else (512, 512, 256)
    g = 32
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float32)
    cfg = BlockScaleConfig()  # 128×128 tiles
    print("format,outlier_exp,nmse_per_tensor,nmse_block128,nmse_mx_group32,"
          "ratio_pt_over_mx,ratio_blk_over_mx")
    for name, mx in MX_FORMATS.items():
        q8 = jnp.float8_e4m3 if "e4m3" in name else jnp.float8_e5m2
        for emax in (0, 8, 16, 24):
            a = jnp.asarray(mx_outlier_matrix(rng, m, k, g, emax),
                            jnp.float32)
            exact = np.asarray(a, np.float64) @ np.asarray(b, np.float64)

            def row_nmse(out):
                err = np.asarray(out, np.float64) - exact
                pw = (exact ** 2).sum(1)
                nz = pw > 0
                return float(np.mean((err ** 2).sum(1)[nz] / pw[nz]))

            e_mx = row_nmse(ops.mx_gemm(a, b, mx_a=name))
            # per-tensor / block baselines use the nearest fp8 dtype (the
            # sub-byte element formats exist only on the MX path)
            e_blk = row_nmse(ops.blockscale_gemm(a, b, q_dtype_a=q8,
                                                 cfg=cfg))
            aq, sa = ops.quantize_tensor(a, q8)
            bq, sb = ops.quantize_tensor(b, q8)
            e_pt = row_nmse(ref.exsdotp_gemm_ref(aq, bq, sa * sb))
            print(f"{name},{emax},{e_pt:.3e},{e_blk:.3e},{e_mx:.3e},"
                  f"{e_pt / max(e_mx, 1e-300):.1f},"
                  f"{e_blk / max(e_mx, 1e-300):.1f}")


def main():
    import os
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        # must happen before the first jax import (sweeps import lazily)
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
    quick = "--quick" in sys.argv
    accuracy_sweep(quick)
    throughput(quick)
    mx_sweep(quick)
    tp_sweep(quick)


if __name__ == "__main__":
    main()
