"""Packed-GEMM throughput sweep vs the machine's own roofline
(EXPERIMENTS.md §gemm_sweep; DESIGN.md §14).

For each (M, K, N) shape × MX format the packed GEMM
(``ops.mx_gemm_packed`` — the honest-storage path the wire/cache gates
already cover byte-wise) is timed end to end and scored against an
*analytic* roofline bound built from two per-run calibrations:

* ``peak_gflops``  — a dense f32 ``jnp.dot`` at the largest swept shape
  (the same MACs the packed kernel must issue; XLA counts 1 MAC =
  2 FLOPs, matching ``benchmarks/roofline.py``);
* ``mem_gbps``     — a device copy of a ~64 MiB buffer (bytes moved =
  read + write).

Reported per shape×format (``BENCH_gemm.json``):

* ``us``                — median-of-iters wall clock, every iteration
  synchronized (``autotune.time_us_median``);
* ``gflops``            — achieved 2·M·N·K / time;
* ``hbm_gbps``          — achieved packed-operand traffic / time
  (payload bytes at the format's true width + E8M0 byte grids + f32
  output — the §10 memory model);
* ``roofline_fraction`` — bound_time / measured_time where bound_time =
  max(flops/peak, bytes/bw): the fraction of this machine's own
  roofline the kernel achieves.  Calibrating per run makes the number
  machine-relative, so a uniformly slower CI runner moves peak and
  kernel together and the gate below stays meaningful;
* ``tiles`` / ``tile_source`` — what the §14 autotune cache holds for
  the shape (``--tune`` populates it by sweeping; without it a cache
  miss reports the static heuristic).

This is CI's perf leg: ``--check BASELINE`` fails (exit 1) when any
quick shape×format's roofline fraction drops >15% below the committed
``benchmarks/baselines/gemm.json`` (improvements never fail; the
baseline is refreshed by re-running with ``--out`` onto it).  Absolute
GFLOPS are informational — only the machine-relative fraction is gated.

Run:
    PYTHONPATH=src python -m benchmarks.gemm_sweep [--quick] [--tune]
        [--out BENCH_gemm.json] [--check benchmarks/baselines/gemm.json]
"""
from __future__ import annotations

import json
import sys

import numpy as np

# quick = the CI-gated cells; the full sweep adds the larger shapes and
# the remaining formats (nightly leg)
QUICK_SHAPES = [(256, 1024, 256)]
FULL_SHAPES = [(256, 1024, 256), (512, 2048, 512), (1024, 4096, 1024)]
QUICK_FORMATS = ["mxfp8e4m3", "mxfp6e2m3", "mxfp4e2m1"]
FULL_FORMATS = ["mxfp8e4m3", "mxfp8e5m2", "mxfp6e2m3", "mxfp6e3m2",
                "mxfp4e2m1"]
GATE_TOL = 1.15        # >15% roofline-fraction regression fails


def _measured_impl():
    """The impl whose wall clock is meaningful on this backend: compiled
    Pallas on TPU, the XLA reference elsewhere (interpret mode is a
    Python emulation — its time measures the emulator, not the kernel).
    Bytes and FLOPs are identical across impls, so the roofline terms
    are the same either way."""
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def calibrate(quick=False):
    """Per-run peak GFLOPS (dense f32 dot) + memory GB/s (device copy)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.autotune import time_us_median

    rng = np.random.default_rng(0)
    n = 512 if quick else 1024
    a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    dot = jax.jit(lambda x, y: jnp.dot(x, y,
                                       preferred_element_type=jnp.float32))
    us = time_us_median(dot, a, b, warmup=2, iters=5)
    peak_gflops = 2 * n * n * n / 1e3 / us

    nb = (2 ** 22 if quick else 2 ** 24)   # elements; f32 → 16/64 MiB
    x = jnp.asarray(rng.standard_normal(nb), jnp.float32)
    cp = jax.jit(lambda v: v + 1.0)        # read + write every byte
    us = time_us_median(cp, x, warmup=2, iters=5)
    mem_gbps = 2 * nb * 4 / 1e3 / us
    return {"peak_gflops": round(peak_gflops, 2),
            "mem_gbps": round(mem_gbps, 2)}


def _packed_bytes(m: int, n: int, k: int, codec, group: int) -> int:
    """HBM bytes the packed GEMM moves: payloads at true width, compact
    E8M0 grids, f32 output."""
    return (codec.packed_cols(k) * m + codec.packed_cols(k) * n
            + (k // group) * (m + n)          # E8M0 scale bytes
            + m * n * 4)                      # f32 output


def measure(quick=False, tune=False):
    import jax.numpy as jnp
    from repro.core.formats import get_mx_format
    from repro.kernels import autotune, ops
    from repro.kernels.codec import get_codec

    impl = _measured_impl()
    cal = calibrate(quick)
    shapes = QUICK_SHAPES if quick else FULL_SHAPES
    formats = QUICK_FORMATS if quick else FULL_FORMATS
    rng = np.random.default_rng(0)
    report = {"backend": impl, "calibration": cal, "entries": {}}
    for m, k, n in shapes:
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        for fmt in formats:
            mx = get_mx_format(fmt)
            codec = get_codec(mx)
            ap, sa8 = ops.mx_quantize(a, mx=fmt, packed=True, impl="xla")
            bp, sb8 = ops.mx_quantize(b.T, mx=fmt, packed=True, impl="xla")
            tune_impl = impl if impl == "pallas" else "pallas_interpret"
            (tiles, db, res) = autotune.gemm_packed_tiles(
                m, n, k, mx, mx, impl=tune_impl, sweep=tune, iters=3)
            run = lambda: ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a=fmt,
                                             impl=impl, tiles="auto")
            us = autotune.time_us_median(run, warmup=1,
                                         iters=3 if quick else 5)
            flops = 2 * m * n * k
            bts = _packed_bytes(m, n, k, codec, mx.group)
            gflops = flops / 1e3 / us
            gbps = bts / 1e3 / us
            # analytic bound on this machine: the slower of compute at
            # calibrated peak and traffic at calibrated BW
            bound_us = max(flops / 1e3 / cal["peak_gflops"],
                           bts / 1e3 / cal["mem_gbps"])
            report["entries"][f"{m}x{k}x{n}|{fmt}"] = {
                "us": round(us, 1),
                "gflops": round(gflops, 2),
                "hbm_gbps": round(gbps, 3),
                "roofline_fraction": round(bound_us / us, 4),
                "tiles": list(tiles) + [int(db)],
                "tile_source": res.source,
            }
    return report


def check(report, baseline_path, tol=GATE_TOL):
    """>15% roofline-fraction regression on any common cell fails."""
    with open(baseline_path) as f:
        base = json.load(f)
    failed = []
    for key, rec in report["entries"].items():
        b = base.get("entries", {}).get(key)
        if b is None:
            continue
        floor = b["roofline_fraction"] / tol
        status = "OK" if rec["roofline_fraction"] >= floor else "REGRESSED"
        print(f"gemm {key}: roofline {rec['roofline_fraction']:.4f} vs "
              f"baseline {b['roofline_fraction']:.4f} "
              f"(floor {floor:.4f}) {status}")
        if rec["roofline_fraction"] < floor:
            failed.append(key)
    return failed


def main():
    args = sys.argv[1:]

    def opt(name, default=None):
        if name in args:
            return args[args.index(name) + 1]
        return default

    report = measure(quick="--quick" in args, tune="--tune" in args)
    out = opt("--out", "BENCH_gemm.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps(report, indent=2, sort_keys=True))
    baseline = opt("--check")
    if baseline:
        failed = check(report, baseline)
        if failed:
            print(f"gemm perf gate FAILED: {failed}")
            raise SystemExit(1)
        print("gemm perf gate passed")


if __name__ == "__main__":
    main()
