"""Packed vs unpacked payload bytes + accuracy across MXFP8/6/4.

Paper context: the MiniFloat-NN story (and the 575 GFLOPS/W headline)
rests on operands staying narrow end to end; DESIGN.md §10's packed
payload pipeline is that claim's memory model.  This sweep measures,
per MX format:

* **payload bytes** of the packed pipeline (``mx_quantize(packed=True)``
  — what the Pallas kernels emit/consume) against the two unpacked
  carriers the refactor removed: byte-wide uint8 codes (1 B/elem — the
  PR 4 "pack at the XLA edge" storage model) and the f32 value carrier
  (4 B/elem — the §8 emulation).  Expect 2x / 1.33x payload-byte
  reduction for FP4 / FP6 vs byte-wide;
* **accuracy**: row-normalized MSE of the packed-ref GEMM vs an f64
  oracle on group-granular outlier data, plus bitwise equality between
  the packed and value paths (packing is lossless);
* a Pallas interpret-mode smoke proving the packed kernel path agrees
  with the XLA reference.

Run:
    PYTHONPATH=src python -m benchmarks.mx_packed_sweep [--quick]
"""
from __future__ import annotations

import sys


def payload_bytes(quick=False):
    import jax.numpy as jnp
    import numpy as np

    from repro.core.formats import MX_FORMATS
    from repro.kernels import ops

    m, k = (64, 512) if quick else (256, 2048)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (m, k)), jnp.float32)
    print("# packed vs unpacked payload bytes "
          f"({m}x{k} = {m * k} elements)")
    print("format,packed_payload_B,scale_B,packed_B_per_elem,"
          "vs_u8_codes,vs_f32_carrier")
    for name, mx in MX_FORMATS.items():
        p, s8 = ops.mx_quantize(x, name, impl="xla", packed=True)
        pb = int(np.prod(p.shape))
        sb = int(np.prod(s8.shape))
        elems = m * k
        bpe = (pb + sb) / elems
        print(f"{name},{pb},{sb},{bpe:.5f},"
              f"{elems / pb:.3f}x,{4 * elems / pb:.3f}x")


def accuracy(quick=False):
    import jax.numpy as jnp
    import numpy as np

    from repro.core.formats import MX_FORMATS
    from repro.kernels import ops

    m, k, n = (64, 256, 64) if quick else (128, 1024, 128)
    rng = np.random.default_rng(1)
    # group-granular outliers: the regime per-tensor scaling flushes
    a = rng.normal(0, 1, (m, k))
    for _ in range(m // 4):
        i = rng.integers(m)
        j = 32 * rng.integers(k // 32)
        a[i, j:j + 32] *= 2.0 ** 16
    b = rng.normal(0, 0.3, (k, n))
    aj = jnp.asarray(a, jnp.float32)
    bj = jnp.asarray(b, jnp.float32)
    exact = a @ b
    print("# packed-GEMM accuracy on group-granular outliers "
          f"({m}x{k}x{n}); packed == value path bitwise")
    print("format,row_nmse,bitwise_equal_to_value_path")
    for name in MX_FORMATS:
        want = ops.mx_gemm(aj, bj, mx_a=name, impl="xla")
        ap, sa8 = ops.mx_quantize(aj, name, impl="xla", packed=True)
        bp, sb8 = ops.mx_quantize(bj.T, name, impl="xla", packed=True)
        got = np.asarray(ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a=name,
                                            impl="xla"), np.float64)
        err = got - exact
        pw = (exact ** 2).sum(1)
        nz = pw > 0
        nmse = float(np.mean((err ** 2).sum(1)[nz] / pw[nz]))
        same = bool(np.array_equal(got, np.asarray(want, np.float64)))
        print(f"{name},{nmse:.3e},{same}")
        assert same, f"{name}: packed path diverged from value path"


def kernel_smoke():
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(-2, 3, (16, 64)), jnp.float32)
    b = jnp.asarray(rng.integers(-2, 3, (64, 24)), jnp.float32)
    print("# Pallas interpret-mode packed kernels == XLA reference "
          "(bit-exact small-int operands)")
    for name in ("mxfp8e4m3", "mxfp6e2m3", "mxfp4e2m1"):
        ap, sa8 = ops.mx_quantize(a, name, impl="pallas_interpret",
                                  packed=True)
        bp, sb8 = ops.mx_quantize(b.T, name, impl="pallas_interpret",
                                  packed=True)
        got = ops.mx_gemm_packed(ap, sa8, bp, sb8, mx_a=name,
                                 impl="pallas_interpret")
        want = ops.mx_gemm(a, b, mx_a=name, impl="xla")
        ok = bool(np.array_equal(np.asarray(got), np.asarray(want)))
        print(f"{name},pallas_interpret_bit_exact,{ok}")
        assert ok, name


def main(quick=False):
    payload_bytes(quick)
    accuracy(quick)
    kernel_smoke()


if __name__ == "__main__":
    main("--quick" in sys.argv)
