"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms (seconds, per chip, TPU v5e):
    compute    = HLO_FLOPs / peak_FLOP/s     (197 TF/s bf16; XLA counts
                                              1 MAC = 2 FLOPs)
    memory     = HLO_bytes  / 819 GB/s HBM
    collective = collective_bytes / 50 GB/s/link ICI

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` of the SPMD
module — they are already *per device*. collective_bytes is parsed from
the compiled HLO text (launch/dryrun.py), result-shape convention,
all-reduce counted twice (RS+AG phases).

MODEL_FLOPS = 6·N·D (dense train) / 2·N·D (inference), N = active
non-embedding params (MoE: experts scaled by top_k/E), + the causal
attention term — computed in launch/dryrun.py and recorded per cell.

Reported per cell:
    * the three terms, the dominant one (the bottleneck),
    * useful-compute ratio = MODEL_FLOPS / (HLO_FLOPs · chips)  — catches
      remat/redundant compute,
    * roofline fraction = (MODEL_FLOPS/chips/peak) / max(term) — the score:
      fraction of peak the step achieves *if* it runs at the roofline bound.

Not a paper table — this is the repo's own TPU-scaling instrument (the
paper's cluster analysis, §IV-B, re-aimed at the v5e mesh).

Run (after generating dry-run artifacts with repro.launch.dryrun):
    PYTHONPATH=src python -m benchmarks.roofline [--dir dryrun_out]
"""
from __future__ import annotations

import glob
import json
import os

PEAK_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9              # B/s per chip
ICI_BW = 50e9               # B/s per link


def load_cells(dryrun_dir: str = "experiments/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _analytic_memory_bytes(rec: dict) -> float:
    """TPU-projected HBM traffic lower bound per device per step.

    The HLO ``bytes`` term inherits CPU fusion granularity (f32 casts,
    small fusion clusters) and overstates what a TPU moves. This bound
    counts what MUST move: parameter+optimizer traffic (weights read
    fwd+bwd, grads written, master/moments read+written) and the
    argument/output buffers the compiled module actually declares.
    """
    mem = rec.get("memory") or {}
    arg = mem.get("argument_bytes", 0)
    out = mem.get("output_bytes", 0)
    # activations: approximate as the compiled temp working set read+written
    # once (remat keeps the live set ~= traffic per microbatch sweep)
    temp = mem.get("temp_bytes", 0)
    return float(arg + out + 2.0 * temp)


def analyze(rec: dict) -> dict:
    if rec.get("status") != "ok":
        return {**rec, "analysis": None}
    t_comp = rec["flops_per_device"] / PEAK_BF16
    t_mem = rec["bytes_per_device"] / HBM_BW
    t_coll = rec["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    chips = rec["n_devices"]
    useful = rec["model_flops_global"] / max(
        rec["flops_per_device"] * chips, 1.0)
    t_model = rec["model_flops_global"] / chips / PEAK_BF16
    frac = t_model / max(max(terms.values()), 1e-12)
    # TPU-projected fraction: memory term from the analytic traffic bound
    # (the HLO bytes term carries CPU-backend fusion granularity)
    t_mem_proj = _analytic_memory_bytes(rec) / HBM_BW
    t_bound_proj = max(t_comp, t_mem_proj, t_coll)
    frac_proj = t_model / max(t_bound_proj, 1e-12)
    return {**rec, "analysis": {
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant, "useful_compute_ratio": useful,
        "roofline_fraction": frac,
        "t_memory_projected_s": t_mem_proj,
        "roofline_fraction_projected": frac_proj,
    }}


_SUGGEST = {
    "compute": ("cut redundant FLOPs (remat policy, fuse quantize ops, "
                "fp8-native MXU path doubles peak)"),
    "memory": ("shrink bytes/step: fp8 operand storage, fused quantization, "
               "larger K-tiles, avoid f32 logit materialization"),
    "collective": ("reshard to cut collectives: overlap with compute, "
                   "compress grads to fp8, avoid resharding between ops"),
}


def to_markdown(cells, *, mesh_filter: str = "pod16x16") -> str:
    rows = []
    head = ("| arch | shape | compute s | memory s | collective s | "
            "dominant | useful | frac | frac(proj) | next lever |")
    sep = "|" + "---|" * 10
    for rec in cells:
        if rec["mesh"] != mesh_filter:
            continue
        if rec.get("status") == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"skipped | — | — | — | {rec['reason'][:40]}… |")
            continue
        a = rec.get("analysis") or analyze(rec)["analysis"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {a['t_compute_s']:.3e} | "
            f"{a['t_memory_s']:.3e} | {a['t_collective_s']:.3e} | "
            f"**{a['dominant']}** | {a['useful_compute_ratio']:.2f} | "
            f"{a['roofline_fraction']:.2f} | "
            f"{a['roofline_fraction_projected']:.2f} | "
            f"{_SUGGEST[a['dominant']][:46]} |")
    return "\n".join([head, sep] + rows)


def compare_markdown(base_cells, opt_cells, mesh="pod16x16") -> str:
    """Baseline (paper-faithful) vs optimized — the §Perf before/after."""
    key = lambda r: (r["arch"], r["shape"])
    base = {key(r): r for r in base_cells if r["mesh"] == mesh}
    rows = ["| arch | shape | coll B/dev (base→opt) | temp GiB (base→opt) |"
            " dominant term s (base→opt) |", "|" + "---|" * 5]
    for r in opt_cells:
        if r["mesh"] != mesh or r.get("status") != "ok":
            continue
        b = base.get(key(r))
        if not b or b.get("status") != "ok":
            continue
        ab = (b.get("analysis") or analyze(b)["analysis"])
        ao = (r.get("analysis") or analyze(r)["analysis"])
        tb = max(ab["t_compute_s"], ab["t_memory_s"], ab["t_collective_s"])
        to = max(ao["t_compute_s"], ao["t_memory_s"], ao["t_collective_s"])
        rows.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{b['collectives']['total_bytes']:.2e}→"
            f"{r['collectives']['total_bytes']:.2e} | "
            f"{b['memory']['temp_bytes']/2**30:.1f}→"
            f"{r['memory']['temp_bytes']/2**30:.1f} | "
            f"{tb:.2f}→{to:.2f} |")
    return "\n".join(rows)


def main(dryrun_dir: str = None):
    base_dir = dryrun_dir or "experiments/dryrun_baseline"
    opt_dir = "experiments/dryrun_opt"
    if not os.path.isdir(base_dir):
        base_dir = "experiments/dryrun"
    cells = [analyze(c) for c in load_cells(base_dir)]
    print("== paper-faithful baseline ==")
    print(to_markdown(cells))
    ok = [c for c in cells if c.get("analysis")]
    print(f"\n{len(ok)} analyzed cells, "
          f"{len(cells) - len(ok)} skipped/failed")
    os.makedirs("experiments", exist_ok=True)
    opt_cells = ([analyze(c) for c in load_cells(opt_dir)]
                 if os.path.isdir(opt_dir) else [])
    with open("experiments/roofline.md", "w") as f:
        f.write("# Roofline — paper-faithful baseline "
                "(single-pod 16x16, per chip)\n\n")
        f.write(to_markdown(cells) + "\n\n")
        f.write("# Multi-pod (2x16x16)\n\n")
        f.write(to_markdown(cells, mesh_filter="pod2x16x16") + "\n\n")
        if opt_cells:
            f.write("# Optimized (§Perf) — single-pod\n\n")
            f.write(to_markdown(opt_cells) + "\n\n")
            f.write("# Baseline → optimized comparison\n\n")
            f.write(compare_markdown(cells, opt_cells) + "\n")
    if opt_cells:
        print("\n== baseline -> optimized ==")
        print(compare_markdown(cells, opt_cells))
    return cells


if __name__ == "__main__":
    main()
