"""Benchmark harness — one entry per paper table/figure + the roofline
table from the dry-run artifacts. Prints ``name,us_per_call,derived`` CSV
for timed sections and structured CSV for modeled/accuracy sections.

Covers: Table II / Fig. 8 (table2_gemm), Table IV (table4_accuracy),
Fig. 7a (fig7_resources), plus the beyond-paper block-scaling sweep
(blockscale_gemm) and the roofline instrument (roofline).

Run:
    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import sys
import time

import numpy as np


def _time_us(fn, *args, warmup=2, iters=10):
    """Median wall-clock microseconds of ``fn(*args)``, each iteration
    synchronized with ``block_until_ready``.

    Timing the loop without per-iteration sync measures dispatch (jax
    enqueues asynchronously and the queue drains after the clock stops),
    and the mean lets one scheduler hiccup skew the number — the
    ``autotune.time_us_median`` convention (EXPERIMENTS.md
    §Conventions).
    """
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def bench_kernels(quick=False):
    """Wall-clock of the expanding-GEMM primitive (CPU, XLA path) vs a
    plain f32 GEMM — the fp8-storage memory win shows up even on CPU."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    print("# kernel microbench (CPU wall-clock; XLA path)")
    print("name,us_per_call,derived")
    rng = np.random.default_rng(0)
    sizes = [(256, 256, 256)] if quick else [(256, 256, 256),
                                             (512, 512, 512),
                                             (1024, 1024, 1024)]
    for m, k, n in sizes:
        a8 = jnp.asarray(rng.normal(0, 1, (m, k)), jnp.float8_e4m3)
        b8 = jnp.asarray(rng.normal(0, 1, (k, n)), jnp.float8_e5m2)
        af = a8.astype(jnp.float32)
        bf = b8.astype(jnp.float32)
        g8 = jax.jit(lambda a, b: ops.exsdotp_gemm(a, b, 1.0, impl="xla"))
        gf = jax.jit(lambda a, b: (a @ b))
        t8 = _time_us(g8, a8, b8)
        tf = _time_us(gf, af, bf)
        gflops = 2 * m * n * k / 1e9
        print(f"exsdotp_gemm_xla_{m}x{k}x{n},{t8:.1f},"
              f"{gflops / (t8 / 1e6):.1f}GFLOP/s")
        print(f"fp32_gemm_{m}x{k}x{n},{tf:.1f},"
              f"{gflops / (tf / 1e6):.1f}GFLOP/s")
        # fused blockwise quantization (memory-roofline primitive)
        x = jnp.asarray(rng.normal(0, 1, (m, k)), jnp.float32)
        q = jax.jit(lambda v: ops.quantize_blockwise(v, jnp.float8_e4m3,
                                                     impl="xla"))
        tq = _time_us(q, x)
        print(f"quant_blockwise_{m}x{k},{tq:.1f},"
              f"{m * k * 4 / (tq / 1e6) / 1e9:.1f}GB/s_read")
    # Pallas interpret-mode timing (Python-level emulation — correctness
    # path only; absolute numbers are not meaningful, recorded for trend)
    a8 = jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float8_e4m3)
    b8 = jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float8_e5m2)
    tp = _time_us(lambda a, b: ops.exsdotp_gemm(
        a, b, 1.0, impl="pallas_interpret", blocks=(32, 32, 32)), a8, b8,
        warmup=1, iters=3)
    print(f"exsdotp_gemm_pallas_interpret_64,{tp:.1f},emulation")


def main() -> None:
    quick = "--quick" in sys.argv
    print("=" * 72)
    print("## Table II / Fig. 8 — GEMM cycles & FLOP/cycle (modeled)")
    from benchmarks import table2_gemm
    table2_gemm.main()
    print("=" * 72)
    print("## Table IV — ExSdotp vs ExFMA accuracy (bit-exact oracle)")
    from benchmarks import table4_accuracy
    # >= 25 draws: single draws are cancellation-conditioned (see module)
    table4_accuracy.main(trials=8 if quick else 25)
    print("=" * 72)
    print("## Fig. 7 — datapath resource proxies + kernel VMEM budget")
    from benchmarks import fig7_resources
    fig7_resources.main()
    print("=" * 72)
    bench_kernels(quick)
    print("=" * 72)
    print("## Block-scaled vs per-tensor GEMM (beyond-paper; outlier sweep)")
    from benchmarks import blockscale_gemm
    blockscale_gemm.accuracy_sweep(quick)
    blockscale_gemm.throughput(quick)
    blockscale_gemm.tp_sweep(quick)  # skips unless >= 8 (forced) devices
    print("=" * 72)
    print("## Packed payload pipeline: bytes + accuracy across MXFP8/6/4 (§10)")
    from benchmarks import mx_packed_sweep
    mx_packed_sweep.main(quick)
    print("=" * 72)
    print("## Packed GEMM vs the machine's own roofline (§14)")
    import json as _json
    from benchmarks import gemm_sweep
    print(_json.dumps(gemm_sweep.measure(quick), indent=2, sort_keys=True))
    print("=" * 72)
    print("## Serving: paged-cache bytes/seq + decode tok/s per policy (§12)")
    import json as _json
    from benchmarks import serve_sweep
    print(_json.dumps(serve_sweep.measure(quick), indent=2, sort_keys=True))
    print("=" * 72)
    print("## Wire bytes per policy across the explicit TP wire (§9)")
    import jax
    if len(jax.devices()) >= 8:
        import json
        from benchmarks import wire_bytes
        print(json.dumps(wire_bytes.measure(quick), indent=2, sort_keys=True))
    else:
        print("(skipped: needs 8 forced host devices; "
              "run python -m benchmarks.wire_bytes)")
    print("=" * 72)
    print("## Roofline (from dry-run artifacts, if present)")
    import os
    if any(os.path.isdir(d) and os.listdir(d) for d in
           ("experiments/dryrun_baseline", "experiments/dryrun_opt",
            "experiments/dryrun")):
        from benchmarks import roofline
        roofline.main()
    else:
        print("(no dry-run artifacts; run python -m repro.launch.dryrun --all)")


if __name__ == "__main__":
    main()
