"""Serving sweep: decode throughput + KV-cache HBM bytes per sequence
across cache policies (DESIGN.md §12).

For each serving policy (``bf16`` carrier pages, ``mxfp8``/``mxfp6``/
``mxfp4`` packed payload + E8M0 pages) the paged cache is built for a
small dense config and a batch of requests runs through the
continuous batcher (``serve.scheduler``); reported per policy:

* ``cache_bytes_per_seq`` — the HBM bytes one sequence's page-pool
  share pins across the layer stack (trash page excluded), measured
  from the actual cache arrays AND cross-checked against the analytic
  ``serve.kv_cache.paged_kv_bytes_per_seq`` — they must agree exactly;
* ``tok_s`` per batch size — host wall-clock through the scheduler
  (CPU/XLA here; informational, not gated — wall time is noisy);
* ``ratios`` — packed-vs-bf16 cache compression.  ``mxfp4`` must hold
  >= 2.5x (the paper-level win the packed pipeline promises; the
  layout arithmetic gives 2.0 / 0.53125 ≈ 3.76x).

This doubles as CI's serving regression gate: ``--check BASELINE``
fails (exit 1) if any policy's cache bytes/sequence grow >10% over the
committed baseline (``benchmarks/baselines/serve.json``) or the mxfp4
compression ratio drops below 2.5x — mirroring the wire-bytes gate.

Run:
    PYTHONPATH=src python -m benchmarks.serve_sweep [--quick]
        [--out BENCH_serve.json] [--check benchmarks/baselines/serve.json]
"""
from __future__ import annotations

import json
import sys
import time

POLICIES = ("bf16", "mxfp8", "mxfp6", "mxfp4")
MIN_MXFP4_RATIO = 2.5


def _cfg(policy):
    from repro.configs.base import ModelConfig
    return ModelConfig(name=f"serve-bench-{policy}", family="dense",
                       n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                       d_ff=128, vocab_size=128, head_dim=32,
                       policy_name=policy, attn_q_chunk=8)


def _pool_bytes_per_seq(cache, mp):
    """Measured pool bytes backing one sequence: per-page bytes of every
    kv leaf (leaves are [L, P, page, KV, W]; nbytes/P is one page across
    the layer stack) times the sequence's max_pages."""
    import jax
    total = 0
    for leaf in jax.tree_util.tree_leaves(cache["kv"]):
        total += leaf.nbytes // leaf.shape[1] * mp
    return total


def measure(quick=False):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import build_model
    from repro.serve.kv_cache import (max_pages, paged_kv_applicable,
                                      paged_kv_bytes_per_seq)
    from repro.serve.scheduler import ContinuousBatcher, ServeRequest

    max_len, page_size = 64, 16
    prompt_len = 6
    new_tokens = 4 if quick else 8
    batches = (2,) if quick else (2, 4)
    mp = max_pages(max_len, page_size)
    report = {"shape": {"max_len": max_len, "page_size": page_size,
                        "prompt_len": prompt_len, "new_tokens": new_tokens,
                        "config": "dense L=2 d=64 H=4 KV=2 hd=32"},
              "policies": {}}
    rng = np.random.default_rng(0)
    for pname in POLICIES:
        cfg = _cfg(pname)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        from repro.core.policy import get_policy
        pol = get_policy(pname)
        cache = model.init_cache(2, max_len, paged=True,
                                 page_size=page_size)
        measured = _pool_bytes_per_seq(cache, mp)
        analytic = paged_kv_bytes_per_seq(cfg, pol, max_len,
                                          page_size=page_size)
        assert measured == analytic, (pname, measured, analytic)
        rec = {"packed": paged_kv_applicable(cfg, pol),
               "cache_format": pol.mx_kv_cache_name or "carrier-bf16",
               "cache_bytes_per_seq": measured,
               "tok_s": {}}
        for batch in batches:
            reqs = [ServeRequest(i, rng.integers(1, cfg.vocab_size,
                                                 prompt_len), new_tokens)
                    for i in range(batch)]
            cb = ContinuousBatcher(model, params, max_batch=batch,
                                   max_len=max_len, page_size=page_size,
                                   impl="auto")
            t0 = time.perf_counter()
            out = cb.run(reqs)
            dt = time.perf_counter() - t0
            assert len(out) == batch
            rec["tok_s"][str(batch)] = round(batch * new_tokens / dt, 2)
        report["policies"][pname] = rec
    base = report["policies"]["bf16"]["cache_bytes_per_seq"]
    report["ratios"] = {
        f"{p}_vs_bf16": round(
            base / report["policies"][p]["cache_bytes_per_seq"], 4)
        for p in POLICIES if p != "bf16"}
    return report


def check(report, baseline_path, tol=1.10):
    """>10% cache-byte regression or a <2.5x mxfp4 ratio fails."""
    with open(baseline_path) as f:
        base = json.load(f)
    failed = []
    for pname, rec in report["policies"].items():
        b = base.get("policies", {}).get(pname)
        if b is None:
            continue
        ratio = rec["cache_bytes_per_seq"] / max(
            b["cache_bytes_per_seq"], 1)
        status = "OK" if ratio <= tol else "REGRESSED"
        print(f"serve-cache {pname}: {rec['cache_bytes_per_seq']} B/seq vs "
              f"baseline {b['cache_bytes_per_seq']} ({ratio:.3f}x) {status}")
        if ratio > tol:
            failed.append(pname)
    r4 = report["ratios"]["mxfp4_vs_bf16"]
    status = "OK" if r4 >= MIN_MXFP4_RATIO else "REGRESSED"
    print(f"serve-cache mxfp4 compression: {r4:.2f}x vs bf16 "
          f"(floor {MIN_MXFP4_RATIO}x) {status}")
    if r4 < MIN_MXFP4_RATIO:
        failed.append("mxfp4_ratio")
    return failed


def main():
    args = sys.argv[1:]

    def opt(name, default=None):
        if name in args:
            return args[args.index(name) + 1]
        return default

    report = measure(quick="--quick" in args)
    out = opt("--out", "BENCH_serve.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps(report, indent=2, sort_keys=True))
    baseline = opt("--check")
    if baseline:
        failed = check(report, baseline)
        if failed:
            print(f"serve regression gate FAILED: {failed}")
            raise SystemExit(1)
        print("serve regression gate passed")


if __name__ == "__main__":
    main()
