"""Paper Table IV reproduction: accuracy of ExSdotp vs ExFMA chains.

Protocol (paper §IV-D): accumulate n in{500,1000,2000} products of
Gaussian inputs quantized to the source precision, using
 (i) low-precision ExSdotp chain (fused pairs, Fig. 9 right),
 (ii) low-precision ExFMA chain (Fig. 9 left),
 (iii) FP64 golden, converted to the destination format for the error.

Reported: relative error vs the FP64 golden. The paper's claim to verify:
ExSdotp error <= ExFMA error for both FP16->FP32 and FP8->FP16, with the
gap growing at smaller bitwidths.

Reproduces: paper Table IV (chain-accumulation accuracy).

Run:
    PYTHONPATH=src python -m benchmarks.table4_accuracy
"""
from __future__ import annotations

import numpy as np

from repro.core import exsdotp as X
from repro.core import formats as F


def run_once(src: str, dst: str, n: int, seed: int):
    rng = np.random.default_rng(seed)
    a = F.quantize_np(rng.normal(0, 1, n), src)
    b = F.quantize_np(rng.normal(0, 1, n), src)
    golden = F.quantize_np(np.float64(a @ b), dst)
    fused = X.exsdotp_chain_np(a, b, src, dst)
    casc = X.exfma_chain_np(a, b, src, dst)
    denom = max(abs(float(golden)), 1e-12)
    return (abs(fused - golden) / denom, abs(casc - golden) / denom)


def main(trials: int = 25):
    """The paper reports single draws and notes the results "vary with the
    selected number of inputs" (cancellation conditions the relative
    error). We therefore report the MEDIAN over ``trials`` draws plus the
    paired win-rate (fraction of draws with fused error <= cascade error),
    which is the statistically meaningful form of the Table IV claim."""
    print("op,format,n,median_relerr_vs_fp64")
    rows = []
    for src, dst, label in [("fp16", "fp32", "FP16-to-FP32"),
                            ("fp8", "fp16", "FP8-to-FP16")]:
        for n in (500, 1000, 2000):
            ef, ec = [], []
            for t in range(trials):
                f, c = run_once(src, dst, n, seed=1000 + t)
                ef.append(f)
                ec.append(c)
            wins = float(np.mean([a <= b for a, b in zip(ef, ec)]))
            rows.append((label, n, float(np.median(ef)),
                         float(np.median(ec)), wins))
            print(f"ExSdotp,{label},{n},{np.median(ef):.3e}")
            print(f"ExFMA,{label},{n},{np.median(ec):.3e}")
            print(f"winrate,{label},{n},{wins:.2f}")
    for label in ("FP16-to-FP32", "FP8-to-FP16"):
        sel = [(f, c, w) for (l, n, f, c, w) in rows if l == label]
        mf = np.median([f for f, _, _ in sel])
        mc = np.median([c for _, c, _ in sel])
        wr = np.mean([w for _, _, w in sel])
        # the paired win-rate is the robust form of the claim (medians of
        # few draws are cancellation-noisy); >50% of draws fused <= cascade
        verdict = "CONFIRMED" if wr >= 0.5 else "NOT CONFIRMED"
        print(f"claim,ExSdotp<=ExFMA {label},median {mf:.3e} vs {mc:.3e},"
              f"winrate {wr:.2f},{verdict}")
    return rows


if __name__ == "__main__":
    main()
