"""Paper Fig. 7 analogue: datapath-resource accounting, ExSdotp vs a
cascade of two ExFMAs (no silicon here — bit-level area proxies).

Area proxy per unit (standard arithmetic-unit scaling):
  * multiplier  ~ p^2            (array multiplier, p = precision bits)
  * adder       ~ w              (w = internal adder width)
  * shifter     ~ w log2 w       (alignment barrel shifter)
  * norm/round  ~ w log2 w       (LZC + normalization shifter + rounder)

ExSdotp (paper Fig. 4):   2 multipliers (p_src), one 3-term sorted adder
  at 2*p_dst+3 .. 2*p_dst+p_src+5 bits, ONE normalize/round at the end.
2x ExFMA cascade:          2 multipliers, 2 aligners, 2 wide adders
  (~3*p_dst each), TWO normalize/round stages; and to match the fused
  unit's throughput each FMA must run at 2x clock (paper §IV-A), which
  the proxy folds in as a 1.3x effort factor on the cascade datapath.

Also reported: VMEM working set per kernel tile configuration — the TPU
"scratchpad area" the Pallas ExSdotp GEMM claims (kernels/exsdotp_gemm.py).

Reproduces: paper Fig. 7a (resource/area comparison, as bit-level proxies).

Run:
    PYTHONPATH=src python -m benchmarks.fig7_resources
"""
from __future__ import annotations

import math


def _unit(p_src: int, p_dst: int, fused: bool) -> float:
    mul = 2 * p_src ** 2
    if fused:
        w3 = 2 * p_dst + p_src + 5
        shift = 2 * (w3 * math.log2(w3))          # two alignment shifts
        add = 2 * w3                               # two carry-propagate adds
        norm = w3 * math.log2(w3)                  # ONE normalize/round
        return mul + shift + add + norm
    wf = 3 * p_dst
    per_fma = (wf * math.log2(wf)) + wf + (wf * math.log2(wf))
    # (the paper's cascade additionally runs each FMA at 2x clock to match
    # throughput; that timing pressure is *why* its synthesized area gap is
    # ~30% — the proxy stays constraint-neutral and lands in the same range)
    return mul + 2 * per_fma


def main():
    print("config,fused_proxy,cascade_proxy,saving_pct,paper_pct")
    for name, ps, pd in [("8to16", 4, 11), ("16to32", 11, 24)]:
        f = _unit(ps, pd, fused=True)
        c = _unit(ps, pd, fused=False)
        print(f"{name},{f:.0f},{c:.0f},{100*(1-f/c):.0f},~30")
    # VMEM working set of the Pallas kernel tiles (fp8 src, fp32 acc)
    print("kernel_tile,bm,bn,bk,vmem_bytes")
    for bm, bn, bk, srcb in [(128, 128, 512, 1), (128, 128, 256, 2),
                             (256, 256, 512, 1)]:
        vmem = bm * bk * srcb + bk * bn * srcb + bm * bn * 4 + bm * bn * 2
        print(f"exsdotp_gemm,{bm},{bn},{bk},{vmem}")
    return None


if __name__ == "__main__":
    main()
