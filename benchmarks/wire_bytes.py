"""Wire bytes + accuracy per policy across the explicit TP wire.

For each quantized policy (``hfp8`` per-tensor scales, ``hfp8_block``
f32 scale grids, ``mxfp8``/``mxfp6``/``mxfp4`` narrow payloads — native
fp8 bytes or packed sub-byte codec lanes — + packed E8M0 byte grids —
DESIGN.md §9/§10), the fwd+bwd column-parallel TP GEMM is compiled on a
forced (data=2, model=4) host mesh and its optimized HLO is fed through
``launch/hlo_analysis`` — the same trip-count-weighted collective-byte
accounting the dry-run cells use, now with fractional sub-byte element
sizes.  Reported per policy: total collective wire bytes, the per-type
breakdown, and forward accuracy (row-normalized MSE vs an f64 oracle)
on group-granular outlier data.

A second section reports the packed sub-byte storage layer
(``kernels/codec.py``): payload bytes and elements/byte for every MX
format — FP4 must measure 2 elements per byte, FP6 four per three.

A third section (``kernel_hbm``) measures the packed *pipeline* HBM
footprint per MX policy: the bytes every GEMM-operand payload + scale
grid of one fwd+bwd step actually occupies under
``mx_quantize(packed=True)`` — the buffers the packed Pallas kernels
emit and consume.  FP4 payload buffers must measure 0.5 B/elem (FP6
0.75) end to end; no byte-wide intermediate exists between quantize
and GEMM.

A fourth section (``attn_kv``) measures the packed attention-KV tiles
(DESIGN.md §11): the k + v payload + scale bytes the flash sweep
streams per layer under each MX policy's ``mx_attn`` format — mxfp4 KV
must measure 0.53125 B/elem, same arithmetic as the GEMM payloads but
with groups along the head dimension.

A fifth section (``dp_grad``) measures the compressed DP gradient wire
(DESIGN.md §13): bytes one replica ships per step (packed payloads +
E8M0 grids under ``Policy.mx_dp_grad``, per-leaf fp8 otherwise) and the
single-step NMSE vs the exact mean on an outlier-heavy gradient tree —
packed MXFP6 must ship <=0.40x the bf16 bytes at NMSE no worse than the
per-leaf fp8 path.  A sixth (``moe_a2a``) compiles the expert-parallel
MoE dispatch per policy and counts its all-to-all bytes plus the
dispatch wire's roundtrip NMSE.

This doubles as CI's regression gate: ``--check BASELINE`` fails
(exit 1) if any policy's wire bytes — or its packed-pipeline HBM /
packed-KV / DP-gradient / MoE-dispatch bytes and NMSE — regress >10%
over the committed baseline (``benchmarks/baselines/wire_bytes.json``).

Run:
    PYTHONPATH=src python -m benchmarks.wire_bytes [--quick]
        [--out BENCH_wire.json] [--check benchmarks/baselines/wire_bytes.json]
"""
from __future__ import annotations

import json
import sys


def measure(quick=False):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.compat import make_mesh, set_mesh
    from repro.core.formats import MX_FORMATS
    from repro.core.policy import get_policy
    from repro.kernels import ops
    from repro.launch.hlo_analysis import analyze
    from repro.parallel.sharding import make_rules
    from repro.parallel.tp_gemm import tp_column_linear

    assert len(jax.devices()) >= 8, "run via __main__ (forces 8 devices)"
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = make_rules(mesh, seq_shard=True)
    b, s, k, n = (4, 32, 64, 128) if quick else (4, 64, 256, 256)
    rng = np.random.default_rng(0)

    # group-granular outliers: one hot 32-span per row — the regime
    # per-tensor scaling flushes and MX groups resolve
    x = rng.normal(0, 1, (b, s, k))
    for i in range(b * s // 4):
        bi, si = rng.integers(b), rng.integers(s)
        j = 32 * rng.integers(k // 32)
        x[bi, si, j:j + 32] *= 2.0 ** 16
    w = rng.normal(0, 0.3, (k, n))
    xj = jnp.asarray(x, jnp.bfloat16)
    wj = jnp.asarray(w, jnp.bfloat16)
    exact = (np.asarray(xj, np.float64).reshape(-1, k)
             @ np.asarray(wj, np.float64))

    report = {"shape": {"B": b, "S": s, "K": k, "N": n,
                        "mesh": "data=2,model=4"},
              "policies": {}}
    for pname in ("hfp8", "hfp8_block", "mxfp8", "mxfp6", "mxfp4"):
        pol = get_policy(pname)

        def loss(x, w):
            return (tp_column_linear(x, w, pol, rules)
                    .astype(jnp.float32) ** 2).sum()

        with set_mesh(mesh):
            fn = jax.jit(jax.value_and_grad(loss, (0, 1)))
            hlo = fn.lower(xj, wj).compile().as_text()
            y = jax.jit(lambda x, w: tp_column_linear(x, w, pol, rules))(
                xj, wj)
        res = analyze(hlo)
        err = np.asarray(y, np.float64).reshape(-1, n) - exact
        pw = (exact ** 2).sum(1)
        nz = pw > 0
        nmse = float(np.mean((err ** 2).sum(1)[nz] / pw[nz]))
        report["policies"][pname] = {
            "coll_total": res["coll_total"],
            "coll_bytes": {t: v for t, v in res["coll_bytes"].items() if v},
            "nmse": nmse,
        }

    # packed storage: the honest bytes-per-element table
    report["packed"] = {}
    xq = jnp.asarray(rng.normal(0, 1, (s, k)), jnp.float32)
    for name, mx in MX_FORMATS.items():
        p, s8 = ops.mx_quantize(xq, name, impl="xla", packed=True)
        elems = s * k
        report["packed"][name] = {
            "elements": elems,
            "payload_bytes": int(np.prod(p.shape)),
            "scale_bytes": int(np.prod(s8.shape)),
            "elems_per_payload_byte": elems / int(np.prod(p.shape)),
            "bytes_per_element": (int(np.prod(p.shape))
                                  + int(np.prod(s8.shape))) / elems,
        }

    # packed-pipeline HBM footprint per MX policy (DESIGN.md §10): the
    # payload + scale buffers one fwd+bwd qlinear step materializes —
    # exactly what the packed quantize kernels emit and the packed GEMM
    # consumes.  Deterministic (array-level, not fusion-dependent), so
    # the >10% gate also covers memory-footprint regressions.
    report["kernel_hbm"] = {}
    x3 = jnp.asarray(rng.normal(0, 1, (b, s, k)), jnp.float32)
    w2 = jnp.asarray(rng.normal(0, 0.3, (k, n)), jnp.float32)
    g3 = jnp.asarray(rng.normal(0, 1, (b, s, n)), jnp.float32)
    for pname in ("mxfp8", "mxfp6", "mxfp4"):
        pol = get_policy(pname)
        bufs = {
            # fwd: x along K, w.T along K; dgrad: g along N, w along N;
            # wgrad: x.T and g.T along tokens (the linear.py roles)
            "fwd_act": ops.mx_quantize(x3, pol.mx_fwd, impl="xla",
                                       packed=True),
            "fwd_w": ops.mx_quantize(w2.T, pol.mx_fwd, impl="xla",
                                     packed=True),
            "dgrad_grad": ops.mx_quantize(g3, pol.mx_bwd_name, impl="xla",
                                          packed=True),
            "dgrad_w": ops.mx_quantize(w2, pol.mx_fwd, impl="xla",
                                       packed=True),
            "wgrad_act": ops.mx_quantize(
                x3.reshape(-1, k).T, pol.mx_wgrad_act_name, impl="xla",
                packed=True),
            "wgrad_grad": ops.mx_quantize(
                g3.reshape(-1, n).T, pol.mx_wgrad_grad_name, impl="xla",
                packed=True),
        }
        rec = {}
        total = 0
        for role, (p, s8) in bufs.items():
            pb, sb = int(np.prod(p.shape)), int(np.prod(s8.shape))
            rec[role] = {"payload_bytes": pb, "scale_bytes": sb}
            total += pb + sb
        elems_fwd = b * s * k
        rec["fwd_act_bytes_per_element"] = (
            bufs["fwd_act"][0].size + bufs["fwd_act"][1].size) / elems_fwd
        rec["total_bytes"] = total
        report["kernel_hbm"][pname] = rec

    # packed attention-KV tiles (DESIGN.md §11): the k + v payload and
    # scale buffers one attention layer's flash sweep streams from HBM
    # (and stores as the backward residuals) under each MX policy's
    # ``mx_attn`` format — groups of 32 along the head dimension.
    report["attn_kv"] = {}
    bh, t, hd = (4, 32, 64) if quick else (8, 128, 64)
    kv = jnp.asarray(rng.normal(0, 1, (bh, t, hd)), jnp.float32)
    for pname in ("mxfp8", "mxfp6", "mxfp4"):
        pol = get_policy(pname)
        kp, ks8 = ops.mx_quantize_kv(kv, pol.mx_attn_name, impl="xla")
        vp, vs8 = ops.mx_quantize_kv(kv, pol.mx_attn_name, impl="xla")
        payload = int(np.prod(kp.shape)) + int(np.prod(vp.shape))
        scales = int(np.prod(ks8.shape)) + int(np.prod(vs8.shape))
        report["attn_kv"][pname] = {
            "format": pol.mx_attn_name,
            "elements": 2 * bh * t * hd,
            "payload_bytes": payload,
            "scale_bytes": scales,
            "total_bytes": payload + scales,
            "bytes_per_element": (payload + scales) / (2 * bh * t * hd),
        }

    # compressed DP gradient wire (DESIGN.md §13): bytes one replica
    # ships per step and single-step NMSE vs the exact mean, on an
    # outlier-heavy gradient tree — the regime where the per-leaf f32
    # scale flushes everything but the hot leaf's outlier and the
    # group-32 E8M0 grids keep resolving the rest.
    from repro.optim.grad_compress import (compressed_psum_mean,
                                           dp_wire_bytes_per_step,
                                           error_feedback_init)
    gshapes = {"w_in": (64, 256), "w_out": (256, 64), "bias": (256,),
               "emb": (96, 64)}
    gtree = {}
    for gname, sh in gshapes.items():
        g = rng.normal(0, 1e-3, sh)
        flatg = g.reshape(-1)
        # sparse, *severe* outliers (2^36: enough to push the rest of
        # the leaf below fp8-e5m2's subnormal floor under one shared
        # f32 scale) — the laundering regime group-32 grids resolve
        hot = rng.integers(flatg.size, size=max(1, flatg.size // 4096))
        flatg[hot] *= 2.0 ** 36
        gtree[gname] = jnp.asarray(flatg.reshape(sh), jnp.float32)
    n_elems = sum(int(np.prod(sh)) for sh in gshapes.values())
    bf16_bytes = 2 * n_elems

    def row_nmse(red):
        # row-normalized (256-element spans) so the handful of outliers
        # can't launder the flushed mass out of the metric — same
        # normalization idea as the TP section's per-row MSE
        ratios = []
        for gname, g in gtree.items():
            ref = np.asarray(g, np.float64).reshape(-1)
            err = np.asarray(red[gname], np.float64).reshape(-1) - ref
            rows = -(-ref.size // 256) * 256
            refp = np.zeros(rows); refp[:ref.size] = ref
            errp = np.zeros(rows); errp[:ref.size] = err
            pw = (refp.reshape(-1, 256) ** 2).sum(1)
            ratios.append(((errp.reshape(-1, 256) ** 2).sum(1)[pw > 0]
                           / pw[pw > 0]))
        return float(np.mean(np.concatenate(ratios)))

    report["dp_grad"] = {"elements": n_elems, "bf16_bytes": bf16_bytes}
    ef0 = error_feedback_init(gtree)
    for pname in ("fp8_leaf", "mxfp8", "mxfp6", "mxfp4"):
        mx = None if pname == "fp8_leaf" else get_policy(pname).mx_dp_grad
        with set_mesh(mesh):
            red, _ = jax.jit(lambda g, e: compressed_psum_mean(
                g, e, mesh, "data", mx=mx))(gtree, ef0)
        wire = dp_wire_bytes_per_step(gtree, mx=mx)
        report["dp_grad"][pname] = {
            "format": mx or "fp8e5m2_per_leaf",
            "wire_bytes": wire,
            "bytes_vs_bf16": wire / bf16_bytes,
            "nmse": row_nmse(red),
        }
    # the tentpole's acceptance bar: packed MXFP6 gradient wire ships
    # <=0.40x the bf16 bytes at NMSE no worse than the per-leaf fp8 path
    assert report["dp_grad"]["mxfp6"]["bytes_vs_bf16"] <= 0.40, \
        report["dp_grad"]["mxfp6"]
    assert (report["dp_grad"]["mxfp6"]["nmse"]
            <= report["dp_grad"]["fp8_leaf"]["nmse"]), report["dp_grad"]

    # MoE dispatch all-to-all (DESIGN.md §13): compile the EP path per
    # policy on the same mesh and count its all-to-all bytes through
    # hlo_analysis (packed payloads + E8M0 grids under MX policies, raw
    # carrier bf16 otherwise), plus the dispatch wire's roundtrip NMSE
    # on the send buffer.
    import dataclasses as _dc

    from repro.configs import get_arch
    from repro.models import moe as MOE
    from repro.parallel.tp_gemm import _deq_mx, _quant_mx
    from repro.core.formats import get_mx_format
    mcfg = get_arch("granite-moe-3b-a800m")
    mcfg = _dc.replace(mcfg, d_model=64, d_ff=128, n_experts=8, top_k=2,
                       capacity_factor=1.5, moe_dense_ff=0)
    mp = MOE.init_moe(jax.random.PRNGKey(0), mcfg, jnp.bfloat16)
    xm = jnp.asarray(rng.normal(0, 1, (4, 32, mcfg.d_model)), jnp.bfloat16)
    buf = jnp.asarray(rng.normal(0, 1, (4, 96, mcfg.d_model)), jnp.float32)
    report["moe_a2a"] = {}
    for pname in ("bf16", "mxfp8", "mxfp6", "mxfp4"):
        pol = get_policy(pname)
        with set_mesh(mesh):
            fn = jax.jit(lambda x, p: MOE.moe_ffn_ep(
                x, p, mcfg, pol, rules=rules)[0])
            hlo = fn.lower(xm, mp).compile().as_text()
        res = analyze(hlo)
        a2a = res["coll_bytes"].get("all-to-all", 0.0)
        if pol.mx:
            mxf = get_mx_format(pol.mx_fwd)
            deq = _deq_mx(*_quant_mx(buf, mxf), mxf)
            nmse = float(jnp.mean((deq - buf) ** 2)
                         / jnp.mean(buf ** 2))
        else:
            nmse = float(jnp.mean(
                (buf.astype(jnp.bfloat16).astype(jnp.float32) - buf) ** 2)
                / jnp.mean(buf ** 2))
        report["moe_a2a"][pname] = {
            "format": pol.mx_fwd or "bf16",
            "a2a_bytes": a2a,
            "coll_total": res["coll_total"],
            "dispatch_nmse": nmse,
        }
    # packed wires must actually shrink the hop vs the carrier a2a
    assert (report["moe_a2a"]["mxfp6"]["a2a_bytes"]
            < report["moe_a2a"]["bf16"]["a2a_bytes"]), report["moe_a2a"]
    return report


def check(report, baseline_path, tol=1.10):
    """>10% wire-byte regression vs the committed baseline fails."""
    with open(baseline_path) as f:
        base = json.load(f)
    failed = []
    for pname, rec in report["policies"].items():
        b = base.get("policies", {}).get(pname)
        if b is None:
            continue
        ratio = rec["coll_total"] / max(b["coll_total"], 1.0)
        status = "OK" if ratio <= tol else "REGRESSED"
        print(f"wire-bytes {pname}: {rec['coll_total']:.0f} vs baseline "
              f"{b['coll_total']:.0f} ({ratio:.3f}x) {status}")
        if ratio > tol:
            failed.append(pname)
    for name, rec in report["packed"].items():
        b = base.get("packed", {}).get(name)
        if b and rec["elems_per_payload_byte"] < b["elems_per_payload_byte"]:
            print(f"packed {name}: {rec['elems_per_payload_byte']} "
                  f"elems/byte < baseline {b['elems_per_payload_byte']}")
            failed.append(name)
    # packed-pipeline HBM footprint: a policy's per-step payload+scale
    # bytes growing >10% means something un-packed (or re-widened)
    for pname, rec in report.get("kernel_hbm", {}).items():
        b = base.get("kernel_hbm", {}).get(pname)
        if b is None:
            continue
        ratio = rec["total_bytes"] / max(b["total_bytes"], 1.0)
        status = "OK" if ratio <= tol else "REGRESSED"
        print(f"kernel-hbm {pname}: {rec['total_bytes']} vs baseline "
              f"{b['total_bytes']} ({ratio:.3f}x) {status}")
        if ratio > tol:
            failed.append(f"kernel_hbm:{pname}")
    # packed attention-KV tiles (§11): the flash sweep's HBM operands —
    # growth means the KV payloads stopped being packed
    for pname, rec in report.get("attn_kv", {}).items():
        b = base.get("attn_kv", {}).get(pname)
        if b is None:
            continue
        ratio = rec["total_bytes"] / max(b["total_bytes"], 1.0)
        status = "OK" if ratio <= tol else "REGRESSED"
        print(f"attn-kv {pname}: {rec['total_bytes']} vs baseline "
              f"{b['total_bytes']} ({ratio:.3f}x) {status}")
        if ratio > tol:
            failed.append(f"attn_kv:{pname}")
    # compressed DP gradient wire (§13): both the shipped bytes and the
    # outlier-sweep NMSE are gated — un-packing the payload or breaking
    # the group grids shows up in one or the other
    for pname, rec in report.get("dp_grad", {}).items():
        b = base.get("dp_grad", {}).get(pname)
        if not isinstance(rec, dict) or b is None:
            continue
        br = rec["wire_bytes"] / max(b["wire_bytes"], 1.0)
        nr = rec["nmse"] / max(b["nmse"], 1e-300)
        status = "OK" if (br <= tol and nr <= tol) else "REGRESSED"
        print(f"dp-grad {pname}: {rec['wire_bytes']} B ({br:.3f}x), "
              f"nmse {rec['nmse']:.3e} ({nr:.3f}x) {status}")
        if br > tol:
            failed.append(f"dp_grad:{pname}:bytes")
        if nr > tol:
            failed.append(f"dp_grad:{pname}:nmse")
    # MoE dispatch all-to-all (§13): same two-sided gate on the EP
    # path's collective bytes and the dispatch roundtrip NMSE
    for pname, rec in report.get("moe_a2a", {}).items():
        b = base.get("moe_a2a", {}).get(pname)
        if b is None:
            continue
        br = rec["a2a_bytes"] / max(b["a2a_bytes"], 1.0)
        nr = rec["dispatch_nmse"] / max(b["dispatch_nmse"], 1e-300)
        status = "OK" if (br <= tol and nr <= tol) else "REGRESSED"
        print(f"moe-a2a {pname}: {rec['a2a_bytes']:.0f} B ({br:.3f}x), "
              f"nmse {rec['dispatch_nmse']:.3e} ({nr:.3f}x) {status}")
        if br > tol:
            failed.append(f"moe_a2a:{pname}:bytes")
        if nr > tol:
            failed.append(f"moe_a2a:{pname}:nmse")
    return failed


def main():
    import os
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        # must happen before the first jax import (measure imports lazily)
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
    args = sys.argv[1:]

    def opt(name, default=None):
        if name in args:
            return args[args.index(name) + 1]
        return default

    report = measure(quick="--quick" in args)
    out = opt("--out", "BENCH_wire.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps(report, indent=2, sort_keys=True))
    baseline = opt("--check")
    if baseline:
        failed = check(report, baseline)
        if failed:
            print(f"wire-byte regression gate FAILED: {failed}")
            raise SystemExit(1)
        print("wire-byte regression gate passed")


if __name__ == "__main__":
    main()
