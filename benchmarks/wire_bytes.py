"""Wire bytes + accuracy per policy across the explicit TP wire.

For each quantized policy (``hfp8`` per-tensor scales, ``hfp8_block``
f32 scale grids, ``mxfp8``/``mxfp6``/``mxfp4`` narrow payloads — native
fp8 bytes or packed sub-byte codec lanes — + packed E8M0 byte grids —
DESIGN.md §9/§10), the fwd+bwd column-parallel TP GEMM is compiled on a
forced (data=2, model=4) host mesh and its optimized HLO is fed through
``launch/hlo_analysis`` — the same trip-count-weighted collective-byte
accounting the dry-run cells use, now with fractional sub-byte element
sizes.  Reported per policy: total collective wire bytes, the per-type
breakdown, and forward accuracy (row-normalized MSE vs an f64 oracle)
on group-granular outlier data.

A second section reports the packed sub-byte storage layer
(``kernels/codec.py``): payload bytes and elements/byte for every MX
format — FP4 must measure 2 elements per byte, FP6 four per three.

A third section (``kernel_hbm``) measures the packed *pipeline* HBM
footprint per MX policy: the bytes every GEMM-operand payload + scale
grid of one fwd+bwd step actually occupies under
``mx_quantize(packed=True)`` — the buffers the packed Pallas kernels
emit and consume.  FP4 payload buffers must measure 0.5 B/elem (FP6
0.75) end to end; no byte-wide intermediate exists between quantize
and GEMM.

A fourth section (``attn_kv``) measures the packed attention-KV tiles
(DESIGN.md §11): the k + v payload + scale bytes the flash sweep
streams per layer under each MX policy's ``mx_attn`` format — mxfp4 KV
must measure 0.53125 B/elem, same arithmetic as the GEMM payloads but
with groups along the head dimension.

This doubles as CI's regression gate: ``--check BASELINE`` fails
(exit 1) if any policy's wire bytes — or its packed-pipeline HBM /
packed-KV bytes — regress >10% over the committed baseline
(``benchmarks/baselines/wire_bytes.json``).

Run:
    PYTHONPATH=src python -m benchmarks.wire_bytes [--quick]
        [--out BENCH_wire.json] [--check benchmarks/baselines/wire_bytes.json]
"""
from __future__ import annotations

import json
import sys


def measure(quick=False):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.compat import make_mesh, set_mesh
    from repro.core.formats import MX_FORMATS
    from repro.core.policy import get_policy
    from repro.kernels import ops
    from repro.launch.hlo_analysis import analyze
    from repro.parallel.sharding import make_rules
    from repro.parallel.tp_gemm import tp_column_linear

    assert len(jax.devices()) >= 8, "run via __main__ (forces 8 devices)"
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = make_rules(mesh, seq_shard=True)
    b, s, k, n = (4, 32, 64, 128) if quick else (4, 64, 256, 256)
    rng = np.random.default_rng(0)

    # group-granular outliers: one hot 32-span per row — the regime
    # per-tensor scaling flushes and MX groups resolve
    x = rng.normal(0, 1, (b, s, k))
    for i in range(b * s // 4):
        bi, si = rng.integers(b), rng.integers(s)
        j = 32 * rng.integers(k // 32)
        x[bi, si, j:j + 32] *= 2.0 ** 16
    w = rng.normal(0, 0.3, (k, n))
    xj = jnp.asarray(x, jnp.bfloat16)
    wj = jnp.asarray(w, jnp.bfloat16)
    exact = (np.asarray(xj, np.float64).reshape(-1, k)
             @ np.asarray(wj, np.float64))

    report = {"shape": {"B": b, "S": s, "K": k, "N": n,
                        "mesh": "data=2,model=4"},
              "policies": {}}
    for pname in ("hfp8", "hfp8_block", "mxfp8", "mxfp6", "mxfp4"):
        pol = get_policy(pname)

        def loss(x, w):
            return (tp_column_linear(x, w, pol, rules)
                    .astype(jnp.float32) ** 2).sum()

        with set_mesh(mesh):
            fn = jax.jit(jax.value_and_grad(loss, (0, 1)))
            hlo = fn.lower(xj, wj).compile().as_text()
            y = jax.jit(lambda x, w: tp_column_linear(x, w, pol, rules))(
                xj, wj)
        res = analyze(hlo)
        err = np.asarray(y, np.float64).reshape(-1, n) - exact
        pw = (exact ** 2).sum(1)
        nz = pw > 0
        nmse = float(np.mean((err ** 2).sum(1)[nz] / pw[nz]))
        report["policies"][pname] = {
            "coll_total": res["coll_total"],
            "coll_bytes": {t: v for t, v in res["coll_bytes"].items() if v},
            "nmse": nmse,
        }

    # packed storage: the honest bytes-per-element table
    report["packed"] = {}
    xq = jnp.asarray(rng.normal(0, 1, (s, k)), jnp.float32)
    for name, mx in MX_FORMATS.items():
        p, s8 = ops.mx_quantize(xq, name, impl="xla", packed=True)
        elems = s * k
        report["packed"][name] = {
            "elements": elems,
            "payload_bytes": int(np.prod(p.shape)),
            "scale_bytes": int(np.prod(s8.shape)),
            "elems_per_payload_byte": elems / int(np.prod(p.shape)),
            "bytes_per_element": (int(np.prod(p.shape))
                                  + int(np.prod(s8.shape))) / elems,
        }

    # packed-pipeline HBM footprint per MX policy (DESIGN.md §10): the
    # payload + scale buffers one fwd+bwd qlinear step materializes —
    # exactly what the packed quantize kernels emit and the packed GEMM
    # consumes.  Deterministic (array-level, not fusion-dependent), so
    # the >10% gate also covers memory-footprint regressions.
    report["kernel_hbm"] = {}
    x3 = jnp.asarray(rng.normal(0, 1, (b, s, k)), jnp.float32)
    w2 = jnp.asarray(rng.normal(0, 0.3, (k, n)), jnp.float32)
    g3 = jnp.asarray(rng.normal(0, 1, (b, s, n)), jnp.float32)
    for pname in ("mxfp8", "mxfp6", "mxfp4"):
        pol = get_policy(pname)
        bufs = {
            # fwd: x along K, w.T along K; dgrad: g along N, w along N;
            # wgrad: x.T and g.T along tokens (the linear.py roles)
            "fwd_act": ops.mx_quantize(x3, pol.mx_fwd, impl="xla",
                                       packed=True),
            "fwd_w": ops.mx_quantize(w2.T, pol.mx_fwd, impl="xla",
                                     packed=True),
            "dgrad_grad": ops.mx_quantize(g3, pol.mx_bwd_name, impl="xla",
                                          packed=True),
            "dgrad_w": ops.mx_quantize(w2, pol.mx_fwd, impl="xla",
                                       packed=True),
            "wgrad_act": ops.mx_quantize(
                x3.reshape(-1, k).T, pol.mx_wgrad_act_name, impl="xla",
                packed=True),
            "wgrad_grad": ops.mx_quantize(
                g3.reshape(-1, n).T, pol.mx_wgrad_grad_name, impl="xla",
                packed=True),
        }
        rec = {}
        total = 0
        for role, (p, s8) in bufs.items():
            pb, sb = int(np.prod(p.shape)), int(np.prod(s8.shape))
            rec[role] = {"payload_bytes": pb, "scale_bytes": sb}
            total += pb + sb
        elems_fwd = b * s * k
        rec["fwd_act_bytes_per_element"] = (
            bufs["fwd_act"][0].size + bufs["fwd_act"][1].size) / elems_fwd
        rec["total_bytes"] = total
        report["kernel_hbm"][pname] = rec

    # packed attention-KV tiles (DESIGN.md §11): the k + v payload and
    # scale buffers one attention layer's flash sweep streams from HBM
    # (and stores as the backward residuals) under each MX policy's
    # ``mx_attn`` format — groups of 32 along the head dimension.
    report["attn_kv"] = {}
    bh, t, hd = (4, 32, 64) if quick else (8, 128, 64)
    kv = jnp.asarray(rng.normal(0, 1, (bh, t, hd)), jnp.float32)
    for pname in ("mxfp8", "mxfp6", "mxfp4"):
        pol = get_policy(pname)
        kp, ks8 = ops.mx_quantize_kv(kv, pol.mx_attn_name, impl="xla")
        vp, vs8 = ops.mx_quantize_kv(kv, pol.mx_attn_name, impl="xla")
        payload = int(np.prod(kp.shape)) + int(np.prod(vp.shape))
        scales = int(np.prod(ks8.shape)) + int(np.prod(vs8.shape))
        report["attn_kv"][pname] = {
            "format": pol.mx_attn_name,
            "elements": 2 * bh * t * hd,
            "payload_bytes": payload,
            "scale_bytes": scales,
            "total_bytes": payload + scales,
            "bytes_per_element": (payload + scales) / (2 * bh * t * hd),
        }
    return report


def check(report, baseline_path, tol=1.10):
    """>10% wire-byte regression vs the committed baseline fails."""
    with open(baseline_path) as f:
        base = json.load(f)
    failed = []
    for pname, rec in report["policies"].items():
        b = base.get("policies", {}).get(pname)
        if b is None:
            continue
        ratio = rec["coll_total"] / max(b["coll_total"], 1.0)
        status = "OK" if ratio <= tol else "REGRESSED"
        print(f"wire-bytes {pname}: {rec['coll_total']:.0f} vs baseline "
              f"{b['coll_total']:.0f} ({ratio:.3f}x) {status}")
        if ratio > tol:
            failed.append(pname)
    for name, rec in report["packed"].items():
        b = base.get("packed", {}).get(name)
        if b and rec["elems_per_payload_byte"] < b["elems_per_payload_byte"]:
            print(f"packed {name}: {rec['elems_per_payload_byte']} "
                  f"elems/byte < baseline {b['elems_per_payload_byte']}")
            failed.append(name)
    # packed-pipeline HBM footprint: a policy's per-step payload+scale
    # bytes growing >10% means something un-packed (or re-widened)
    for pname, rec in report.get("kernel_hbm", {}).items():
        b = base.get("kernel_hbm", {}).get(pname)
        if b is None:
            continue
        ratio = rec["total_bytes"] / max(b["total_bytes"], 1.0)
        status = "OK" if ratio <= tol else "REGRESSED"
        print(f"kernel-hbm {pname}: {rec['total_bytes']} vs baseline "
              f"{b['total_bytes']} ({ratio:.3f}x) {status}")
        if ratio > tol:
            failed.append(f"kernel_hbm:{pname}")
    # packed attention-KV tiles (§11): the flash sweep's HBM operands —
    # growth means the KV payloads stopped being packed
    for pname, rec in report.get("attn_kv", {}).items():
        b = base.get("attn_kv", {}).get(pname)
        if b is None:
            continue
        ratio = rec["total_bytes"] / max(b["total_bytes"], 1.0)
        status = "OK" if ratio <= tol else "REGRESSED"
        print(f"attn-kv {pname}: {rec['total_bytes']} vs baseline "
              f"{b['total_bytes']} ({ratio:.3f}x) {status}")
        if ratio > tol:
            failed.append(f"attn_kv:{pname}")
    return failed


def main():
    import os
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        # must happen before the first jax import (measure imports lazily)
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()
    args = sys.argv[1:]

    def opt(name, default=None):
        if name in args:
            return args[args.index(name) + 1]
        return default

    report = measure(quick="--quick" in args)
    out = opt("--out", "BENCH_wire.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(json.dumps(report, indent=2, sort_keys=True))
    baseline = opt("--check")
    if baseline:
        failed = check(report, baseline)
        if failed:
            print(f"wire-byte regression gate FAILED: {failed}")
            raise SystemExit(1)
        print("wire-byte regression gate passed")


if __name__ == "__main__":
    main()
