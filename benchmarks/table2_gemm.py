"""Paper Table II / Fig. 8: GEMM cycles & FLOP/cycle on the MiniFloat-NN
cluster — reproduced as a calibrated performance model + measured wall
time of our kernels.

No RISC-V RTL here, so cycles are modeled from first principles of the
paper's cluster (§III-E/IV-B):

  * 8 compute cores; per-core peak: 2 FLOP/cycle FP64 FMA, SIMD width
    64-bit -> 4 FLOP/cycle FP32, 8 FLOP/cycle FP16 (non-expanding FMA),
    ExSdotp: 8 FLOP/cycle 16->32-bit, 16 FLOP/cycle 8->16-bit;
  * SSR/FREP hide loads/loop overhead inside the steady state; per
    (m-tile x n-row) there is a setup overhead (stream config + register
    init) plus the final Vsum reduction of SIMD partial accumulators;
  * the expanding kernels halve the reduction count vs FMA kernels
    (paper: "halves the number of intermediate results").

cycles = flops / (cores * flop_per_cycle) * (1/steady_eff) + tiles * setup

The model is calibrated with a single (steady_eff, setup) pair shared by
all kernels, then compared against every cycle count in Table II — the
derived quantities the paper highlights (1.96x FP8 vs FP16 FLOP/cycle at
128x256/128x128, 7.23x vs FP64, 2x peak vs ExFMA) are recomputed from the
model and from the paper's own numbers.

Reproduces: paper Table II and Fig. 8 (GEMM cycles / FLOP-per-cycle).

Run:
    PYTHONPATH=src python -m benchmarks.table2_gemm
"""
from __future__ import annotations

import numpy as np

CORES = 8
FLOP_PER_CYCLE = {  # per core
    "fp64_fma": 2, "fp32_fma": 4, "fp16_fma": 8,
    "exsdotp_16_32": 8, "exsdotp_8_16": 16,
}
# Table II (paper): kernel -> {(M,N): cycles}; K == M (square-ish tiles,
# GEMM size rows denote M x N with K = M per the kernel listing).
PAPER_TABLE2 = {
    "fp64_fma": {(64, 64): 37306},
    "fp32_fma": {(64, 64): 20195, (64, 128): 38058},
    "fp16_fma": {(64, 64): 12232, (64, 128): 20726, (128, 128): 83890},
    "exsdotp_16_32": {(64, 64): 10968, (64, 128): 20169, (128, 128): 80709},
    "exsdotp_8_16": {(64, 64): 7019, (64, 128): 11165, (128, 128): 43244,
                     (128, 256): 82501},
}


def model_cycles(kernel: str, m: int, n: int, k: int,
                 steady_eff: float, setup: float) -> float:
    flops = 2.0 * m * n * k
    peak = CORES * FLOP_PER_CYCLE[kernel]
    steady = flops / peak / steady_eff
    # per-core row tiles: rows m split over cores; setup per row strip
    tiles = (m / CORES) * (n / 8)   # unrolled 8-column strips (paper kernel)
    return steady + setup * tiles


def calibrate():
    """Least-squares fit of (1/steady_eff, setup) on Table II."""
    rows = []
    ys = []
    for kern, cases in PAPER_TABLE2.items():
        for (m, n), cyc in cases.items():
            k = m
            flops = 2.0 * m * n * k
            peak = CORES * FLOP_PER_CYCLE[kern]
            rows.append([flops / peak, (m / CORES) * (n / 8)])
            ys.append(cyc)
    A = np.asarray(rows)
    y = np.asarray(ys)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    inv_eff, setup = float(coef[0]), float(coef[1])
    return 1.0 / inv_eff, setup


def main():
    eff, setup = calibrate()
    print(f"model,steady_eff,{eff:.3f},setup_cycles_per_tile,{setup:.1f}")
    print("kernel,gemm,paper_cycles,model_cycles,err_pct")
    errs = []
    for kern, cases in PAPER_TABLE2.items():
        for (m, n), cyc in cases.items():
            mc = model_cycles(kern, m, n, m, eff, setup)
            err = 100 * (mc - cyc) / cyc
            errs.append(abs(err))
            print(f"{kern},{m}x{n},{cyc},{mc:.0f},{err:+.1f}")
    print(f"model,mean_abs_err_pct,{np.mean(errs):.1f}")

    # paper's derived claims, recomputed from the paper's own numbers
    fc = lambda kern, m, n: 2 * m * n * m / PAPER_TABLE2[kern][(m, n)]
    r1 = fc("exsdotp_8_16", 128, 256) / fc("exsdotp_16_32", 128, 128)
    r2 = fc("exsdotp_8_16", 128, 256) / fc("fp64_fma", 64, 64)
    print(f"claim,fp8/fp16 flop-per-cycle ratio,paper 1.96x,ours {r1:.2f}x")
    print(f"claim,fp8/fp64 flop-per-cycle ratio,paper 7.23x,ours {r2:.2f}x")
    # Fig. 8 analogue: FLOP/cycle per format/size (from model)
    print("fig8,kernel,gemm,flop_per_cycle")
    for kern, cases in PAPER_TABLE2.items():
        for (m, n) in cases:
            print(f"fig8,{kern},{m}x{n},{fc(kern, m, n):.2f}")
    return eff, setup


if __name__ == "__main__":
    main()
